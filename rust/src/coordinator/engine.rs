//! The unified execution-backend abstraction.
//!
//! The coordinator used to hard-code an `if xla / else tree` branch per
//! job; both backends now sit behind the [`Engine`] trait — Step 1
//! (`density`) and Step 2 (`dependents`) as separate calls so staged
//! sessions can cache each, with Step 3 (union-find linkage) always in Rust
//! on the caller's side. The [`super::Router`] hands out `Arc<dyn Engine>`
//! per resolved backend.

use std::sync::{Arc, Weak};

use crate::dpc::{self, DensityAlgo, DensityModel, DepAlgo};
use crate::error::DpcError;
use crate::geom::{Dtype, DynPoints, PointSet, PointStore, Scalar};
use crate::runtime::engine::D_PAD;
use crate::runtime::{XlaDpcOutput, XlaService};
use crate::sync::{rank, OrderedMutex};

/// Shape and algorithm choices of one clustering job — what an engine needs
/// for capability checks ([`Engine::supports`]) and per-job overrides.
#[derive(Clone, Copy, Debug)]
pub struct JobSpec {
    pub n: usize,
    pub d: usize,
    pub d_cut: f64,
    /// Coordinate precision of the payload (the payload is authoritative;
    /// [`JobSpec::from_payload`] derives this field from it).
    pub dtype: Dtype,
    /// Step-2 algorithm (tree backend only; brute-force backends ignore it).
    pub dep_algo: DepAlgo,
    /// Step-1 variant (tree backend only).
    pub density_algo: DensityAlgo,
    /// Density definition (capability-gated: the XLA artifacts hard-code
    /// the cutoff count, so other models route to the tree engine).
    pub density: DensityModel,
}

impl JobSpec {
    pub fn new<S: Scalar>(pts: &PointStore<S>, d_cut: f64) -> Self {
        JobSpec {
            n: pts.len(),
            d: pts.dim(),
            d_cut,
            dtype: S::DTYPE,
            dep_algo: DepAlgo::Priority,
            density_algo: DensityAlgo::TreePruned,
            density: DensityModel::CutoffCount,
        }
    }

    /// Spec for a queued payload (dtype taken from the payload's tag).
    pub fn from_payload(pts: &DynPoints, d_cut: f64) -> Self {
        JobSpec {
            n: pts.len(),
            d: pts.dim(),
            d_cut,
            dtype: pts.dtype(),
            dep_algo: DepAlgo::Priority,
            density_algo: DensityAlgo::TreePruned,
            density: DensityModel::CutoffCount,
        }
    }

    pub fn dep_algo(mut self, a: DepAlgo) -> Self {
        self.dep_algo = a;
        self
    }

    pub fn density_model(mut self, m: DensityModel) -> Self {
        self.density = m;
        self
    }
}

/// An execution backend for Steps 1–2 of the DPC pipeline. Payloads are
/// precision-tagged; engines advertise which dtypes they take via
/// [`Engine::supports`] (the router falls back to the tree engine, which
/// takes both).
pub trait Engine: Send + Sync {
    fn name(&self) -> &'static str;

    /// Can this engine execute a job of the given shape?
    fn supports(&self, job: &JobSpec) -> bool;

    /// Step 1: ρ(x) for every point at radius `job.d_cut`, under the
    /// job's [`DensityModel`].
    fn density(&self, pts: &DynPoints, job: &JobSpec) -> Result<Vec<u32>, DpcError>;

    /// Step 2: λ(x) per point — `None` for points below `rho_min` and the
    /// global peak. Candidate sets are threshold-free (pass `rho_min = 0.0`
    /// for the full forest used by cached sessions).
    fn dependents(
        &self,
        pts: &DynPoints,
        rho: &[u32],
        rho_min: f64,
        job: &JobSpec,
    ) -> Result<Vec<Option<u32>>, DpcError>;
}

/// The Rust tree engine: the paper's algorithm suite. Exact per precision,
/// any size, dimension, dtype, and density model.
#[derive(Debug)]
pub struct TreeEngine;

impl Engine for TreeEngine {
    fn name(&self) -> &'static str {
        "tree"
    }

    fn supports(&self, _job: &JobSpec) -> bool {
        true
    }

    fn density(&self, pts: &DynPoints, job: &JobSpec) -> Result<Vec<u32>, DpcError> {
        Ok(match pts {
            DynPoints::F32(p) => dpc::compute_density_model(p, job.d_cut, job.density, job.density_algo),
            DynPoints::F64(p) => dpc::compute_density_model(p, job.d_cut, job.density, job.density_algo),
        })
    }

    fn dependents(
        &self,
        pts: &DynPoints,
        rho: &[u32],
        rho_min: f64,
        job: &JobSpec,
    ) -> Result<Vec<Option<u32>>, DpcError> {
        Ok(match pts {
            DynPoints::F32(p) => dpc::dep::compute_dependents(p, rho, rho_min, job.dep_algo),
            DynPoints::F64(p) => dpc::dep::compute_dependents(p, rho, rho_min, job.dep_algo),
        })
    }
}

/// The AOT-compiled XLA brute-force engine, adapted to the trait.
///
/// One PJRT execution produces both ρ and λ; since the trait splits the
/// steps, the adapter memoizes recent (point set, radius) outputs so each
/// job's `density` → `dependents` sequence executes once — including when
/// several workers interleave jobs (one slot per in-flight point set, not a
/// single global slot). Each memo keys on the store's **shared coordinate
/// buffer** (`Arc<[f64]>`) — the allocation every refcount sibling of a
/// store agrees on — via a `Weak`: the weak count pins the allocation, so
/// a pointer match can never be a recycled address from a dropped job, and
/// dead entries are pruned on insert.
pub struct XlaEngine {
    svc: Arc<XlaService>,
    memo: OrderedMutex<Vec<Memo>, { rank::ENGINE_MEMO }>,
}

impl std::fmt::Debug for XlaEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("XlaEngine").field("capacity", &self.svc.capacity()).finish_non_exhaustive()
    }
}

/// More concurrent XLA jobs than this re-execute instead of caching.
const MEMO_CAP: usize = 16;

struct Memo {
    buf: Weak<[f64]>,
    /// Shape of the store the output was computed for: one buffer can back
    /// stores of different shapes (`PointStore::try_from_shared` re-views
    /// the same `Arc<[f64]>` under another dimension), so buffer identity
    /// alone would serve a wrong-length ρ to a reshaped sibling.
    n: usize,
    d: usize,
    d_cut_bits: u64,
    out: XlaDpcOutput,
}

impl XlaEngine {
    pub fn new(svc: Arc<XlaService>) -> Self {
        XlaEngine { svc, memo: OrderedMutex::new(Vec::new()) }
    }

    pub fn capacity(&self) -> usize {
        self.svc.capacity()
    }

    fn run_memo(&self, pts: &PointSet, d_cut: f64) -> Result<XlaDpcOutput, DpcError> {
        let bits = d_cut.to_bits();
        let buf = pts.shared_coords();
        {
            let memo = self.memo.lock();
            if let Some(m) = memo.iter().find(|m| {
                std::ptr::eq(m.buf.as_ptr(), Arc::as_ptr(&buf))
                    && m.n == pts.len()
                    && m.d == pts.dim()
                    && m.d_cut_bits == bits
            }) {
                return Ok(m.out.clone());
            }
        }
        // The service takes `Arc<PointSet>`; wrapping a store clone is a
        // refcount bump on `buf`, never a coordinate copy.
        let out = self
            .svc
            .run(Arc::new(pts.clone()), d_cut)
            .map_err(|e| DpcError::Backend { engine: "xla".into(), message: e.to_string() })?;
        let mut memo = self.memo.lock();
        memo.retain(|m| m.buf.strong_count() > 0);
        if memo.len() >= MEMO_CAP {
            memo.remove(0);
        }
        memo.push(Memo {
            buf: Arc::downgrade(&buf),
            n: pts.len(),
            d: pts.dim(),
            d_cut_bits: bits,
            out: out.clone(),
        });
        Ok(out)
    }
}

/// Extract the f64 store an XLA job runs over. The router never sends f32
/// payloads here (`supports` gates on dtype), so the error is defensive.
fn xla_f64(pts: &DynPoints) -> Result<&PointSet, DpcError> {
    match pts {
        DynPoints::F64(p) => Ok(p),
        DynPoints::F32(_) => Err(DpcError::Backend {
            engine: "xla".into(),
            message: "f32 payloads route to the tree engine (the XLA artifacts are compiled for f64 inputs)".into(),
        }),
    }
}

impl Engine for XlaEngine {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn supports(&self, job: &JobSpec) -> bool {
        job.n <= self.svc.capacity()
            && job.d <= D_PAD
            && job.dtype == Dtype::F64
            // The AOT artifacts hard-code the cutoff count; other density
            // models fall back to the tree engine via the router.
            && job.density == DensityModel::CutoffCount
    }

    fn density(&self, pts: &DynPoints, job: &JobSpec) -> Result<Vec<u32>, DpcError> {
        Ok(self.run_memo(xla_f64(pts)?, job.d_cut)?.rho)
    }

    fn dependents(
        &self,
        pts: &DynPoints,
        rho: &[u32],
        rho_min: f64,
        job: &JobSpec,
    ) -> Result<Vec<Option<u32>>, DpcError> {
        let out = self.run_memo(xla_f64(pts)?, job.d_cut)?;
        // Noise handling mirrors the tree engine: noise points get no λ.
        Ok(rho
            .iter()
            .zip(&out.dep)
            .map(|(&r, &d)| if (r as f64) < rho_min { None } else { d })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpc::DpcParams;
    use crate::prng::SplitMix64;
    use crate::proputil::gen_clustered_points;

    #[test]
    fn tree_engine_matches_direct_pipeline() {
        let mut rng = SplitMix64::new(77);
        let pts = gen_clustered_points(&mut rng, 300, 2, 3, 80.0, 2.0);
        let params = DpcParams { d_cut: 4.0, rho_min: 2.0, delta_min: 10.0, ..DpcParams::default() };
        let payload = DynPoints::F64(pts.clone());
        let spec = JobSpec::from_payload(&payload, params.d_cut).dep_algo(DepAlgo::Fenwick);
        assert_eq!(spec.dtype, Dtype::F64);
        let eng = TreeEngine;
        assert!(eng.supports(&spec));
        let rho = eng.density(&payload, &spec).unwrap();
        assert_eq!(rho, dpc::compute_density(&pts, params.d_cut, DensityAlgo::TreePruned));
        let dep = eng.dependents(&payload, &rho, params.rho_min, &spec).unwrap();
        assert_eq!(dep, dpc::dep::compute_dependents(&pts, &rho, params.rho_min, DepAlgo::Fenwick));
    }

    #[test]
    fn tree_engine_runs_f32_payloads() {
        let mut rng = SplitMix64::new(78);
        let pts64 = gen_clustered_points(&mut rng, 200, 2, 3, 60.0, 2.0);
        let pts = PointStore::<f32>::cast_from_f64(&pts64);
        let payload = DynPoints::F32(pts.clone());
        let spec = JobSpec::from_payload(&payload, 4.0);
        assert_eq!(spec.dtype, Dtype::F32);
        let eng = TreeEngine;
        assert!(eng.supports(&spec));
        let rho = eng.density(&payload, &spec).unwrap();
        assert_eq!(rho, dpc::compute_density(&pts, 4.0, DensityAlgo::TreePruned));
        let dep = eng.dependents(&payload, &rho, 0.0, &spec).unwrap();
        assert_eq!(dep, dpc::dep::compute_dependents(&pts, &rho, 0.0, DepAlgo::Priority));
    }

    #[test]
    fn tree_engine_dispatches_density_models() {
        let mut rng = SplitMix64::new(79);
        let pts = gen_clustered_points(&mut rng, 180, 2, 3, 60.0, 2.0);
        let payload = DynPoints::F64(pts.clone());
        for model in DensityModel::REPRESENTATIVE {
            let spec = JobSpec::from_payload(&payload, 4.0).density_model(model);
            let rho = TreeEngine.density(&payload, &spec).unwrap();
            assert_eq!(
                rho,
                dpc::compute_density_model(&pts, 4.0, model, DensityAlgo::TreePruned),
                "{model}"
            );
        }
    }
}
