//! Configuration: a hand-rolled `key = value` format (serde/toml are not
//! available offline). Lines are `key = value`, `#` comments; unknown keys
//! are errors (typo safety). Env overrides via `PARCLUSTER_<KEY>`.
//!
//! Example (`parcluster.conf`):
//!
//! ```text
//! threads = 8
//! backend = auto          # auto | tree | xla
//! dep_algo = priority     # naive | exact-baseline | incomplete | priority | fenwick
//! xla_threshold = 4096
//! artifacts_dir = artifacts
//! workers = 2
//! durable_dir = /var/lib/dpc    # enable the write-ahead journal
//! fsync_every = 1               # 1 = every append, N = group commit, 0 = never
//! journal_rotate_bytes = 67108864  # segment rotation threshold, 0 = never
//! checkpoint_retain = 1            # checkpoint roots kept by GC
//! ```

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::dpc::DepAlgo;

use super::router::Backend;

#[derive(Clone, Debug, PartialEq)]
pub struct CoordinatorConfig {
    /// Parallelism of the compute pool (0 = auto).
    pub threads: usize,
    /// Default routing policy.
    pub backend: Backend,
    /// Default dependent-point algorithm for the tree backend.
    pub dep_algo: DepAlgo,
    /// Auto mode: jobs with n ≤ threshold go to XLA (if artifacts exist).
    pub xla_threshold: usize,
    /// AOT artifacts directory.
    pub artifacts_dir: PathBuf,
    /// Coordinator worker threads (job-level concurrency).
    pub workers: usize,
    /// Durable-serve directory: when set, every state-changing command is
    /// write-ahead-journaled there and `checkpoint` snapshots live state
    /// (see `durability`). `None` = in-memory serve (the default).
    pub durable_dir: Option<PathBuf>,
    /// Journal fsync policy: 1 = fsync every append (default), N = group
    /// commit every N appends, 0 = never (the OS flushes).
    pub fsync_every: u64,
    /// Journal segment rotation threshold in bytes: a segment that would
    /// grow past this rolls over to `journal-<seq+1>.pclj`, and
    /// checkpoints delete whole segments past the replay horizon. 0 =
    /// never rotate (unbounded single segment, the pre-rotation
    /// behaviour). Default 64 MiB.
    pub journal_rotate_bytes: u64,
    /// How many checkpoint *roots* GC keeps (each root pins the prior
    /// checkpoints its delta levels reference). Minimum 1 (the newest).
    pub checkpoint_retain: u64,
    /// TCP listen address for the binary serve front end (e.g.
    /// `127.0.0.1:7401`). `None` = stdin-only serve (the default).
    pub listen_addr: Option<String>,
    /// Admission control: maximum jobs queued or running before
    /// `try_submit`/`submit_recut`/`submit_ingest` reject with
    /// `Backpressure`. 0 = unlimited (the default).
    pub max_inflight_jobs: u64,
    /// Serve admission: maximum open sessions + streams a single tenant id
    /// may hold before opens fail with `QuotaExceeded`. 0 = unlimited.
    pub max_sessions_per_tenant: usize,
    /// Serve admission: global cap on open sessions + streams. When an
    /// open would exceed it, the least-recently-used *idle* session is
    /// evicted (closed) to make room; if every open handle is busy the
    /// open fails with `Backpressure`. 0 = unlimited (the default).
    pub max_open_sessions: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            threads: 0,
            backend: Backend::Auto,
            dep_algo: DepAlgo::Priority,
            xla_threshold: 2048,
            artifacts_dir: crate::runtime::artifacts_dir(),
            workers: 1,
            durable_dir: None,
            fsync_every: 1,
            journal_rotate_bytes: 64 << 20,
            checkpoint_retain: 1,
            listen_addr: None,
            max_inflight_jobs: 0,
            max_sessions_per_tenant: 0,
            max_open_sessions: 0,
        }
    }
}

impl CoordinatorConfig {
    /// Parse the `key = value` text format.
    pub fn parse(text: &str) -> Result<Self> {
        let mut kv: HashMap<String, String> = HashMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let t = line.split('#').next().unwrap_or("").trim();
            if t.is_empty() {
                continue;
            }
            let Some((k, v)) = t.split_once('=') else {
                bail!("config line {}: expected `key = value`, got {t:?}", lineno + 1);
            };
            kv.insert(k.trim().to_string(), v.trim().to_string());
        }
        Self::from_map(kv)
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    fn from_map(kv: HashMap<String, String>) -> Result<Self> {
        let mut cfg = CoordinatorConfig::default();
        for (k, v) in kv {
            match k.as_str() {
                "threads" => cfg.threads = v.parse().context("threads")?,
                "backend" => cfg.backend = parse_backend(&v)?,
                "dep_algo" => cfg.dep_algo = parse_dep_algo(&v)?,
                "xla_threshold" => cfg.xla_threshold = v.parse().context("xla_threshold")?,
                "artifacts_dir" => cfg.artifacts_dir = PathBuf::from(v),
                "workers" => cfg.workers = v.parse::<usize>().context("workers")?.max(1),
                "durable_dir" => cfg.durable_dir = Some(PathBuf::from(v)),
                "fsync_every" => cfg.fsync_every = v.parse().context("fsync_every")?,
                "journal_rotate_bytes" => {
                    cfg.journal_rotate_bytes = v.parse().context("journal_rotate_bytes")?
                }
                "checkpoint_retain" => {
                    cfg.checkpoint_retain = v.parse::<u64>().context("checkpoint_retain")?.max(1)
                }
                "listen_addr" => cfg.listen_addr = Some(v),
                "max_inflight_jobs" => cfg.max_inflight_jobs = v.parse().context("max_inflight_jobs")?,
                "max_sessions_per_tenant" => {
                    cfg.max_sessions_per_tenant = v.parse().context("max_sessions_per_tenant")?
                }
                "max_open_sessions" => cfg.max_open_sessions = v.parse().context("max_open_sessions")?,
                other => bail!("unknown config key {other:?}"),
            }
        }
        Ok(cfg)
    }

    /// Apply env overrides. `PALLAS_THREADS` (or the legacy
    /// `PARCLUSTER_THREADS`) pins the compute pool's parallelism, parsed by
    /// `parlay::pool::env_threads` — the same reader and policy the pool
    /// itself uses for its default, so the knob means the same thing on
    /// every path.
    pub fn with_env_overrides(mut self) -> Result<Self> {
        if let Some(n) = crate::parlay::pool::env_threads() {
            self.threads = n;
        }
        if let Ok(v) = std::env::var("PARCLUSTER_BACKEND") {
            self.backend = parse_backend(&v)?;
        }
        if let Ok(v) = std::env::var("PARCLUSTER_DEP_ALGO") {
            self.dep_algo = parse_dep_algo(&v)?;
        }
        if let Ok(v) = std::env::var("PARCLUSTER_XLA_THRESHOLD") {
            self.xla_threshold = v.parse().context("PARCLUSTER_XLA_THRESHOLD")?;
        }
        if let Ok(v) = std::env::var("PARCLUSTER_DURABLE_DIR") {
            self.durable_dir = Some(PathBuf::from(v));
        }
        if let Ok(v) = std::env::var("PARCLUSTER_FSYNC_EVERY") {
            self.fsync_every = v.parse().context("PARCLUSTER_FSYNC_EVERY")?;
        }
        if let Ok(v) = std::env::var("PARCLUSTER_JOURNAL_ROTATE_BYTES") {
            self.journal_rotate_bytes = v.parse().context("PARCLUSTER_JOURNAL_ROTATE_BYTES")?;
        }
        if let Ok(v) = std::env::var("PARCLUSTER_CHECKPOINT_RETAIN") {
            self.checkpoint_retain =
                v.parse::<u64>().context("PARCLUSTER_CHECKPOINT_RETAIN")?.max(1);
        }
        if let Ok(v) = std::env::var("PARCLUSTER_LISTEN_ADDR") {
            self.listen_addr = Some(v);
        }
        if let Ok(v) = std::env::var("PARCLUSTER_MAX_INFLIGHT_JOBS") {
            self.max_inflight_jobs = v.parse().context("PARCLUSTER_MAX_INFLIGHT_JOBS")?;
        }
        if let Ok(v) = std::env::var("PARCLUSTER_MAX_SESSIONS_PER_TENANT") {
            self.max_sessions_per_tenant = v.parse().context("PARCLUSTER_MAX_SESSIONS_PER_TENANT")?;
        }
        if let Ok(v) = std::env::var("PARCLUSTER_MAX_OPEN_SESSIONS") {
            self.max_open_sessions = v.parse().context("PARCLUSTER_MAX_OPEN_SESSIONS")?;
        }
        Ok(self)
    }
}

pub fn parse_backend(s: &str) -> Result<Backend> {
    Ok(match s {
        "auto" => Backend::Auto,
        "tree" => Backend::TreeExact,
        "xla" => Backend::XlaBruteForce,
        other => bail!("unknown backend {other:?} (auto|tree|xla)"),
    })
}

pub fn parse_dep_algo(s: &str) -> Result<DepAlgo> {
    for a in DepAlgo::ALL {
        if a.name() == s {
            return Ok(a);
        }
    }
    bail!("unknown dep_algo {s:?} (naive|exact-baseline|incomplete|priority|fenwick)")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let cfg = CoordinatorConfig::parse(
            "threads = 4\nbackend = xla # inline comment\ndep_algo = fenwick\nxla_threshold = 999\nworkers = 3\ndurable_dir = /tmp/dpc-wal\nfsync_every = 16\njournal_rotate_bytes = 1048576\ncheckpoint_retain = 3\nlisten_addr = 127.0.0.1:7401\nmax_inflight_jobs = 64\nmax_sessions_per_tenant = 8\nmax_open_sessions = 128\n",
        )
        .unwrap();
        assert_eq!(cfg.threads, 4);
        assert_eq!(cfg.backend, Backend::XlaBruteForce);
        assert_eq!(cfg.dep_algo, DepAlgo::Fenwick);
        assert_eq!(cfg.xla_threshold, 999);
        assert_eq!(cfg.workers, 3);
        assert_eq!(cfg.durable_dir, Some(PathBuf::from("/tmp/dpc-wal")));
        assert_eq!(cfg.fsync_every, 16);
        assert_eq!(cfg.journal_rotate_bytes, 1 << 20);
        assert_eq!(cfg.checkpoint_retain, 3);
        assert_eq!(cfg.listen_addr.as_deref(), Some("127.0.0.1:7401"));
        assert_eq!(cfg.max_inflight_jobs, 64);
        assert_eq!(cfg.max_sessions_per_tenant, 8);
        assert_eq!(cfg.max_open_sessions, 128);
    }

    #[test]
    fn admission_defaults_are_unlimited() {
        let cfg = CoordinatorConfig::default();
        assert_eq!(cfg.listen_addr, None);
        assert_eq!(cfg.max_inflight_jobs, 0);
        assert_eq!(cfg.max_sessions_per_tenant, 0);
        assert_eq!(cfg.max_open_sessions, 0);
        assert!(CoordinatorConfig::parse("max_inflight_jobs = lots\n").is_err());
    }

    #[test]
    fn durability_defaults_off_and_synchronous() {
        let cfg = CoordinatorConfig::default();
        assert_eq!(cfg.durable_dir, None);
        assert_eq!(cfg.fsync_every, 1, "default policy is fsync-per-append");
        assert_eq!(cfg.journal_rotate_bytes, 64 << 20, "default rotation threshold is 64 MiB");
        assert_eq!(cfg.checkpoint_retain, 1, "GC keeps only the newest root by default");
        assert!(CoordinatorConfig::parse("fsync_every = banana\n").is_err());
        assert!(CoordinatorConfig::parse("journal_rotate_bytes = tiny\n").is_err());
        // retain = 0 would leave GC rootless; it is clamped, not rejected.
        assert_eq!(CoordinatorConfig::parse("checkpoint_retain = 0\n").unwrap().checkpoint_retain, 1);
    }

    #[test]
    fn empty_config_is_default() {
        assert_eq!(CoordinatorConfig::parse("# nothing\n\n").unwrap(), CoordinatorConfig::default());
    }

    #[test]
    fn rejects_unknown_keys_and_bad_syntax() {
        assert!(CoordinatorConfig::parse("nope = 1\n").is_err());
        assert!(CoordinatorConfig::parse("just words\n").is_err());
        assert!(CoordinatorConfig::parse("backend = gpu\n").is_err());
        assert!(CoordinatorConfig::parse("dep_algo = quantum\n").is_err());
    }

    #[test]
    fn workers_clamped_to_one() {
        let cfg = CoordinatorConfig::parse("workers = 0\n").unwrap();
        assert_eq!(cfg.workers, 1);
    }
}
