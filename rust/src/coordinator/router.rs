//! Backend routing: decide, per job, whether the tree engine or the
//! AOT-compiled XLA brute-force engine runs it, and hand out the resolved
//! engine as a trait object.

use std::sync::Arc;

use crate::runtime::XlaService;

use super::engine::{Engine, JobSpec, TreeEngine, XlaEngine};

/// Execution backend for a clustering job.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Backend {
    /// Resolve by size at submission time.
    Auto,
    /// Rust tree engine (the paper's algorithms); any n, f64 exact.
    TreeExact,
    /// AOT XLA Θ(n²) engine; n ≤ artifact capacity, f32.
    XlaBruteForce,
}

impl Backend {
    pub fn name(&self) -> &'static str {
        match self {
            Backend::Auto => "auto",
            Backend::TreeExact => "tree",
            Backend::XlaBruteForce => "xla",
        }
    }
}

/// Size-based router over the registered engines.
#[derive(Debug)]
pub struct Router {
    tree: Arc<TreeEngine>,
    xla: Option<Arc<XlaEngine>>,
    xla_threshold: usize,
}

impl Router {
    pub fn new(xla: Option<Arc<XlaService>>, xla_threshold: usize) -> Self {
        Router {
            tree: Arc::new(TreeEngine),
            xla: xla.map(|svc| Arc::new(XlaEngine::new(svc))),
            xla_threshold,
        }
    }

    pub fn has_xla(&self) -> bool {
        self.xla.is_some()
    }

    /// Resolve a (possibly `Auto`) backend request for a job. Falls back to
    /// the tree engine whenever XLA cannot take the job (no artifacts, too
    /// large, d > padded dimension) — capability is the engine's own
    /// [`Engine::supports`] answer, not router-side special cases.
    pub fn resolve(&self, requested: Backend, spec: &JobSpec) -> Backend {
        let xla_ok = self.xla.as_ref().map(|e| e.supports(spec)).unwrap_or(false);
        match requested {
            Backend::TreeExact => Backend::TreeExact,
            Backend::XlaBruteForce => {
                if xla_ok {
                    Backend::XlaBruteForce
                } else {
                    Backend::TreeExact
                }
            }
            Backend::Auto => {
                if xla_ok && spec.n <= self.xla_threshold {
                    Backend::XlaBruteForce
                } else {
                    Backend::TreeExact
                }
            }
        }
    }

    /// The engine for a *resolved* backend (`Auto` maps to the tree engine;
    /// resolve first for size-based routing).
    pub fn engine(&self, backend: Backend) -> Arc<dyn Engine> {
        match backend {
            Backend::XlaBruteForce => match &self.xla {
                Some(e) => Arc::clone(e) as Arc<dyn Engine>,
                None => Arc::clone(&self.tree) as Arc<dyn Engine>,
            },
            Backend::TreeExact | Backend::Auto => Arc::clone(&self.tree) as Arc<dyn Engine>,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpc::DpcParams;
    use crate::geom::PointSet;

    fn spec(n: usize) -> JobSpec {
        let pts = PointSet::new(vec![0.0; n * 2], 2);
        JobSpec::new(&pts, DpcParams::default().d_cut)
    }

    #[test]
    fn without_xla_everything_routes_to_tree() {
        let r = Router::new(None, 4096);
        let s = spec(100);
        assert_eq!(r.resolve(Backend::Auto, &s), Backend::TreeExact);
        assert_eq!(r.resolve(Backend::XlaBruteForce, &s), Backend::TreeExact);
        assert_eq!(r.resolve(Backend::TreeExact, &s), Backend::TreeExact);
        assert!(!r.has_xla());
        assert_eq!(r.engine(Backend::XlaBruteForce).name(), "tree");
        assert_eq!(r.engine(Backend::TreeExact).name(), "tree");
    }

    // Routing with a live engine is exercised in rust/tests/xla_integration.rs.
}
