//! Backend routing: decide, per job, whether the tree engine or the
//! AOT-compiled XLA brute-force engine runs it.

use std::sync::Arc;

use crate::runtime::XlaService;

/// Execution backend for a clustering job.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Backend {
    /// Resolve by size at submission time.
    Auto,
    /// Rust tree engine (the paper's algorithms); any n, f64 exact.
    TreeExact,
    /// AOT XLA Θ(n²) engine; n ≤ artifact capacity, f32.
    XlaBruteForce,
}

impl Backend {
    pub fn name(&self) -> &'static str {
        match self {
            Backend::Auto => "auto",
            Backend::TreeExact => "tree",
            Backend::XlaBruteForce => "xla",
        }
    }
}

/// Size-based router.
pub struct Router {
    xla: Option<Arc<XlaService>>,
    xla_threshold: usize,
}

impl Router {
    pub fn new(xla: Option<Arc<XlaService>>, xla_threshold: usize) -> Self {
        Router { xla, xla_threshold }
    }

    pub fn xla_engine(&self) -> Option<&Arc<XlaService>> {
        self.xla.as_ref()
    }

    /// Resolve a (possibly `Auto`) backend request for a job of `n` points
    /// in `d` dims. Falls back to the tree engine whenever XLA cannot take
    /// the job (no artifacts, too large, d > 8).
    pub fn resolve(&self, requested: Backend, n: usize, d: usize) -> Backend {
        let xla_ok = self
            .xla
            .as_ref()
            .map(|e| n <= e.capacity() && d <= crate::runtime::engine::D_PAD)
            .unwrap_or(false);
        match requested {
            Backend::TreeExact => Backend::TreeExact,
            Backend::XlaBruteForce => {
                if xla_ok {
                    Backend::XlaBruteForce
                } else {
                    Backend::TreeExact
                }
            }
            Backend::Auto => {
                if xla_ok && n <= self.xla_threshold {
                    Backend::XlaBruteForce
                } else {
                    Backend::TreeExact
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn without_xla_everything_routes_to_tree() {
        let r = Router::new(None, 4096);
        assert_eq!(r.resolve(Backend::Auto, 100, 2), Backend::TreeExact);
        assert_eq!(r.resolve(Backend::XlaBruteForce, 100, 2), Backend::TreeExact);
        assert_eq!(r.resolve(Backend::TreeExact, 100, 2), Backend::TreeExact);
    }

    // Routing with a live engine is exercised in rust/tests/xla_integration.rs.
}
