//! L3 coordinator: a clustering job service over the two engines.
//!
//! The paper's contribution is the parallel algorithm suite itself, so the
//! coordinator is the *driver* layer mandated by the three-layer
//! architecture: it owns process lifecycle, a job queue with a worker pool,
//! a backend router, metrics, and the configuration system.
//!
//! Backends (both behind the [`Engine`] trait; the worker pipeline is
//! backend-agnostic):
//! - **TreeExact** — the Rust engine (`crate::dpc`): exact, any n, the
//!   paper's algorithms (priority / fenwick / incomplete / baselines).
//! - **XlaBruteForce** — the AOT-compiled tensorized Θ(n²) DPC
//!   (`crate::runtime`): exact Steps 1–2 in f32, competitive only for small
//!   n (the crossover is measured by `benches/xla_crossover.rs`); Step 3
//!   always runs in Rust.
//! - **Auto** — route by size: n ≤ threshold and artifacts present → XLA,
//!   else trees.
//!
//! Sessions ([`Coordinator::open_session`] / [`Coordinator::submit_recut`])
//! cache Steps 1–2 so decision-graph threshold sweeps pay only Step 3.
//! Streams ([`Coordinator::open_stream`] / [`Coordinator::submit_ingest`])
//! hold a [`crate::dpc::StreamingSession`] so batch arrivals repair Steps
//! 1–2 incrementally instead of re-running them.

pub mod config;
pub mod engine;
pub mod job;
pub mod router;
pub mod service;
pub mod spec;
pub mod metrics;

pub use config::CoordinatorConfig;
pub use engine::{Engine, JobSpec, TreeEngine, XlaEngine};
pub use job::{ClusterJob, JobOutput, JobPayload, JobStatus};
pub use router::{Backend, Router};
pub use service::{Coordinator, JobId, SessionEntry, SessionId, StreamEntry};
pub use spec::{OpenSource, OpenSpec};
