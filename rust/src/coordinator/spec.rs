//! [`OpenSpec`]: the one builder every session/stream open goes through.
//!
//! The coordinator used to expose four open entry points
//! (`open_session`, `open_session_with_model`, `open_stream`,
//! `open_stream_with_model`), and each new per-open knob (density model,
//! dtype, tag) threatened to double the count again. `OpenSpec` collapses
//! them: the *source* (a point set for a one-shot session, a dimension
//! for a stream) plus the radius are required at construction, everything
//! else is a builder default —
//!
//! ```no_run
//! # use std::sync::Arc;
//! # use parcluster::coordinator::{Coordinator, CoordinatorConfig, OpenSpec};
//! # use parcluster::dpc::DensityModel;
//! # use parcluster::geom::PointSet;
//! # let coord = Coordinator::start(CoordinatorConfig::default()).unwrap();
//! # let pts = Arc::new(PointSet::new(vec![0.0, 0.0], 2));
//! let sid = coord.open_session(OpenSpec::points(pts, 3.0).density(DensityModel::GaussianKernel).tag("demo"))?;
//! let stream = coord.open_stream(OpenSpec::dim(2, 3.0))?;
//! # Ok::<(), parcluster::DpcError>(())
//! ```
//!
//! `open_session` requires a points source and `open_stream` a dimension
//! source; handing the wrong kind is a typed [`DpcError::InvalidParam`],
//! never a silent reinterpretation. (The `*_with_model` shims that once
//! forwarded here have been removed; `OpenSpec` is the only open path.)

use std::sync::Arc;

use crate::dpc::DensityModel;
use crate::error::DpcError;
use crate::geom::{Dtype, PointSet};

/// What an open binds to: a full point set (one-shot session) or a
/// dimension (streaming session that ingests batches later).
#[derive(Clone, Debug)]
pub enum OpenSource {
    Points(Arc<PointSet>),
    Dim(usize),
}

/// Builder-style description of a session or stream open. Construct with
/// [`OpenSpec::points`] or [`OpenSpec::dim`], refine with the chained
/// setters, and hand to [`super::Coordinator::open_session`] /
/// [`super::Coordinator::open_stream`].
#[derive(Clone, Debug)]
pub struct OpenSpec {
    source: OpenSource,
    d_cut: f64,
    density: DensityModel,
    dtype: Dtype,
    tag: String,
}

impl OpenSpec {
    /// A one-shot session over `pts` at radius `d_cut` (cutoff-count
    /// density, f64, untagged unless the setters say otherwise).
    pub fn points(pts: Arc<PointSet>, d_cut: f64) -> Self {
        OpenSpec {
            source: OpenSource::Points(pts),
            d_cut,
            density: DensityModel::CutoffCount,
            dtype: Dtype::F64,
            tag: String::new(),
        }
    }

    /// A streaming session over `dim`-dimensional batches at radius
    /// `d_cut`.
    pub fn dim(dim: usize, d_cut: f64) -> Self {
        OpenSpec {
            source: OpenSource::Dim(dim),
            d_cut,
            density: DensityModel::CutoffCount,
            dtype: Dtype::F64,
            tag: String::new(),
        }
    }

    /// The exact density model every job in the session runs under
    /// (default: the paper's cutoff count).
    pub fn density(mut self, model: DensityModel) -> Self {
        self.density = model;
        self
    }

    /// Coordinate precision. Streams honour it end to end — an f32 stream
    /// ingests f32 batches (anything else is a typed
    /// [`DpcError::DtypeMismatch`]) and survives durable recovery at its
    /// own precision. One-shot sessions remain f64 (their payload source
    /// is a [`PointSet`]), so a non-f64 points-source spec fails
    /// [`OpenSpec::validate`].
    pub fn dtype(mut self, dtype: Dtype) -> Self {
        self.dtype = dtype;
        self
    }

    /// Free-form label echoed in job outputs for this session's re-cuts
    /// and ingests (and into serve-mode responses). In-memory only: the
    /// durable journal does not record it, so recovered sessions come
    /// back tagged `"recovered"`.
    pub fn tag(mut self, tag: impl Into<String>) -> Self {
        self.tag = tag.into();
        self
    }

    pub fn source(&self) -> &OpenSource {
        &self.source
    }

    pub fn d_cut_value(&self) -> f64 {
        self.d_cut
    }

    pub fn density_model(&self) -> DensityModel {
        self.density
    }

    pub fn dtype_value(&self) -> Dtype {
        self.dtype
    }

    pub fn tag_value(&self) -> &str {
        &self.tag
    }

    /// Source-independent validation shared by both open entry points.
    pub fn validate(&self) -> Result<(), DpcError> {
        crate::dpc::session::validate_d_cut(self.d_cut)?;
        self.density.validate()?;
        if self.dtype != Dtype::F64 && matches!(self.source, OpenSource::Points(_)) {
            return Err(DpcError::InvalidParam {
                name: "dtype",
                value: self.dtype.size_bytes() as f64,
                requirement: "one-shot sessions are f64 (points sources carry a PointSet); use a stream for f32",
            });
        }
        Ok(())
    }

    /// Unwrap a points source or fail typed.
    pub fn into_points(self) -> Result<(Arc<PointSet>, f64, DensityModel, String), DpcError> {
        match self.source {
            OpenSource::Points(p) => Ok((p, self.d_cut, self.density, self.tag)),
            OpenSource::Dim(_) => Err(DpcError::InvalidParam {
                name: "open_spec",
                value: 0.0,
                requirement: "open_session requires a points source (OpenSpec::points)",
            }),
        }
    }

    /// Unwrap a dimension source or fail typed.
    pub fn into_dim(self) -> Result<(usize, f64, DensityModel, Dtype, String), DpcError> {
        match self.source {
            OpenSource::Dim(d) => Ok((d, self.d_cut, self.density, self.dtype, self.tag)),
            OpenSource::Points(_) => Err(DpcError::InvalidParam {
                name: "open_spec",
                value: 0.0,
                requirement: "open_stream requires a dimension source (OpenSpec::dim)",
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_defaults_and_setters() {
        let spec = OpenSpec::dim(3, 2.5);
        assert_eq!(spec.d_cut_value(), 2.5);
        assert_eq!(spec.density_model(), DensityModel::CutoffCount);
        assert_eq!(spec.dtype_value(), Dtype::F64);
        assert_eq!(spec.tag_value(), "");
        let spec = spec.density(DensityModel::KnnRadius { k: 4 }).tag("t");
        assert_eq!(spec.density_model(), DensityModel::KnnRadius { k: 4 });
        assert_eq!(spec.tag_value(), "t");
        spec.validate().unwrap();
    }

    #[test]
    fn wrong_source_kind_is_typed() {
        let pts = Arc::new(PointSet::new(vec![0.0, 0.0], 2));
        assert!(matches!(
            OpenSpec::points(pts, 1.0).into_dim(),
            Err(DpcError::InvalidParam { name: "open_spec", .. })
        ));
        assert!(matches!(
            OpenSpec::dim(2, 1.0).into_points(),
            Err(DpcError::InvalidParam { name: "open_spec", .. })
        ));
    }

    #[test]
    fn f32_streams_are_accepted_f32_sessions_are_not() {
        let spec = OpenSpec::dim(2, 1.0).dtype(Dtype::F32);
        spec.validate().unwrap();
        let (_, _, _, dtype, _) = spec.into_dim().unwrap();
        assert_eq!(dtype, Dtype::F32);
        let pts = Arc::new(PointSet::new(vec![0.0, 0.0], 2));
        let err = OpenSpec::points(pts, 1.0).dtype(Dtype::F32).validate().unwrap_err();
        assert!(matches!(err, DpcError::InvalidParam { name: "dtype", .. }));
    }

    #[test]
    fn invalid_radius_and_model_fail_validation() {
        assert!(OpenSpec::dim(2, -1.0).validate().is_err());
        assert!(OpenSpec::dim(2, 1.0).density(DensityModel::KnnRadius { k: 0 }).validate().is_err());
    }
}
