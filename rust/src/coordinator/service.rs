//! The coordinator service: job queue + worker pool + router + metrics +
//! session store.
//!
//! Jobs are submitted (non-blocking) and executed by dedicated worker
//! threads; `wait` blocks on a condvar until the job reaches a terminal
//! state. Both backends are driven through the [`Engine`] trait: the worker
//! runs Step 1 (`density`) and Step 2 (`dependents`) on the resolved engine
//! and Step 3 (single-linkage union-find) always in Rust.
//!
//! Sessions ([`Coordinator::open_session`]) cache a point set's density and
//! full dependency forest so [`Coordinator::submit_recut`] jobs — the
//! decision-graph parameter sweeps of §6.2 — execute only the linkage step.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::Instant;

use anyhow::Result;

use crate::dpc::{dep, linkage, session, DpcParams, DpcResult, StepTimings};
use crate::error::DpcError;
use crate::geom::PointSet;
use crate::runtime::XlaService;

use super::config::CoordinatorConfig;
use super::engine::JobSpec;
use super::job::{ClusterJob, JobOutput, JobPayload, JobStatus};
use super::metrics::Metrics;
use super::router::{Backend, Router};

pub type JobId = u64;
pub type SessionId = u64;

/// Cached Steps-1–2 artifacts for one open session: everything a
/// threshold-only re-cut needs.
pub struct SessionEntry {
    pub pts: Arc<PointSet>,
    pub d_cut: f64,
    /// ρ per point at `d_cut`.
    pub rho: Vec<u32>,
    /// Full (unthresholded) dependency forest.
    pub dep: Vec<Option<u32>>,
    /// δ for the full forest.
    pub delta: Vec<f64>,
    /// Name of the engine that built the artifacts.
    pub built_by: &'static str,
    /// Wall-clock seconds the build (Steps 1–2) took.
    pub build_s: f64,
}

struct Shared {
    queue: Mutex<VecDeque<(JobId, ClusterJob)>>,
    queue_cv: Condvar,
    status: Mutex<HashMap<JobId, JobStatus>>,
    status_cv: Condvar,
    shutdown: AtomicBool,
    sessions: Mutex<HashMap<SessionId, Arc<SessionEntry>>>,
}

/// The clustering service. Create with [`Coordinator::start`], submit jobs,
/// `wait` for results, and `shutdown` (also done on drop).
pub struct Coordinator {
    cfg: CoordinatorConfig,
    router: Arc<Router>,
    shared: Arc<Shared>,
    workers: Vec<thread::JoinHandle<()>>,
    next_id: AtomicU64,
    next_session_id: AtomicU64,
    pub metrics: Arc<Metrics>,
}

impl Coordinator {
    /// Start the service. Loads the XLA engine if artifacts are present
    /// (failure to load degrades to tree-only with a warning, never an
    /// error — the tree engine is always available).
    pub fn start(cfg: CoordinatorConfig) -> Result<Self> {
        if cfg.threads > 0 {
            crate::parlay::set_threads(cfg.threads);
        }
        let xla = if cfg.artifacts_dir.join("manifest.txt").exists() {
            match XlaService::start(&cfg.artifacts_dir) {
                Ok(e) => Some(Arc::new(e)),
                Err(e) => {
                    eprintln!("warning: XLA engine unavailable ({e}); tree backend only");
                    None
                }
            }
        } else {
            None
        };
        let router = Arc::new(Router::new(xla, cfg.xla_threshold));
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            queue_cv: Condvar::new(),
            status: Mutex::new(HashMap::new()),
            status_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            sessions: Mutex::new(HashMap::new()),
        });
        let metrics = Arc::new(Metrics::new());
        let workers = (0..cfg.workers)
            .map(|w| {
                let sh = Arc::clone(&shared);
                let rt = Arc::clone(&router);
                let mt = Arc::clone(&metrics);
                let cfg = cfg.clone();
                thread::Builder::new()
                    .name(format!("coord-{w}"))
                    .spawn(move || worker_loop(&sh, &rt, &mt, &cfg))
                    .expect("spawn worker")
            })
            .collect();
        Ok(Coordinator {
            cfg,
            router,
            shared,
            workers,
            next_id: AtomicU64::new(1),
            next_session_id: AtomicU64::new(1),
            metrics,
        })
    }

    pub fn config(&self) -> &CoordinatorConfig {
        &self.cfg
    }

    pub fn has_xla(&self) -> bool {
        self.router.has_xla()
    }

    /// Submit a job; returns immediately.
    pub fn submit(&self, job: ClusterJob) -> JobId {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.shared.status.lock().unwrap().insert(id, JobStatus::Queued);
        self.shared.queue.lock().unwrap().push_back((id, job));
        self.shared.queue_cv.notify_one();
        self.metrics.inc("jobs_submitted");
        id
    }

    /// Open a session: validate the input, run Steps 1–2 once through the
    /// routed engine, and cache the artifacts for threshold-only re-cuts.
    /// Synchronous — the build is the expensive part the session exists to
    /// amortize, so callers should see its cost exactly once.
    pub fn open_session(&self, pts: Arc<PointSet>, d_cut: f64) -> Result<SessionId, DpcError> {
        session::validate_points(&pts)?;
        session::validate_d_cut(d_cut)?;
        let spec = JobSpec::new(&pts, d_cut).dep_algo(self.cfg.dep_algo);
        let backend = self.router.resolve(self.cfg.backend, &spec);
        let engine = self.router.engine(backend);
        let t = Instant::now();
        let rho = engine.density(&pts, &spec)?;
        // rho_min = 0: the full forest, so any later threshold is a mask.
        let dep = engine.dependents(&pts, &rho, 0.0, &spec)?;
        let delta = dep::dependent_distances(&pts, &dep);
        let build_s = t.elapsed().as_secs_f64();
        let entry = Arc::new(SessionEntry {
            pts,
            d_cut,
            rho,
            dep,
            delta,
            built_by: engine.name(),
            build_s,
        });
        let id = self.next_session_id.fetch_add(1, Ordering::Relaxed);
        self.shared.sessions.lock().unwrap().insert(id, entry);
        self.metrics.inc("sessions_opened");
        Ok(id)
    }

    /// Look up an open session's cached artifacts.
    pub fn session(&self, id: SessionId) -> Option<Arc<SessionEntry>> {
        self.shared.sessions.lock().unwrap().get(&id).cloned()
    }

    /// Submit a linkage-only re-cut of an open session at new thresholds.
    pub fn submit_recut(&self, id: SessionId, rho_min: f64, delta_min: f64) -> Result<JobId, DpcError> {
        session::validate_thresholds(rho_min, delta_min)?;
        let entry = self.session(id).ok_or(DpcError::UnknownSession(id))?;
        let params = DpcParams { d_cut: entry.d_cut, rho_min, delta_min };
        let job = ClusterJob::recut(id, params).tag(format!("recut:{id}"));
        self.metrics.inc("recuts_submitted");
        Ok(self.submit(job))
    }

    /// Drop a session's cached artifacts. Returns whether it existed;
    /// re-cuts already dequeued keep their `Arc` and complete.
    pub fn close_session(&self, id: SessionId) -> bool {
        self.shared.sessions.lock().unwrap().remove(&id).is_some()
    }

    /// Current status (non-blocking).
    pub fn status(&self, id: JobId) -> Option<JobStatus> {
        self.shared.status.lock().unwrap().get(&id).cloned()
    }

    /// Block until the job completes; returns the output or the failure
    /// message.
    pub fn wait(&self, id: JobId) -> Result<JobOutput, String> {
        let mut st = self.shared.status.lock().unwrap();
        loop {
            match st.get(&id) {
                None => return Err(format!("unknown job {id}")),
                Some(s) if s.is_terminal() => {
                    return match s.clone() {
                        JobStatus::Done(out) => Ok(*out),
                        JobStatus::Failed(msg) => Err(msg),
                        _ => unreachable!(),
                    };
                }
                _ => st = self.shared.status_cv.wait(st).unwrap(),
            }
        }
    }

    /// Convenience: submit + wait.
    pub fn run_sync(&self, job: ClusterJob) -> Result<JobOutput, String> {
        let id = self.submit(job);
        self.wait(id)
    }

    pub fn shutdown(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.queue_cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_loop(sh: &Shared, router: &Router, metrics: &Metrics, cfg: &CoordinatorConfig) {
    loop {
        let (id, job) = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if sh.shutdown.load(Ordering::Acquire) {
                    return;
                }
                if let Some(item) = q.pop_front() {
                    break item;
                }
                q = sh.queue_cv.wait(q).unwrap();
            }
        };
        set_status(sh, id, JobStatus::Running);
        let t = Instant::now();
        let (outcome, backend) = run_job(&job, sh, router, cfg);
        let wall = t.elapsed().as_secs_f64();
        metrics.inc(&format!("jobs_{}", backend.name()));
        metrics.observe_secs("job_wall", wall);
        if let Ok(result) = &outcome {
            metrics.add("points_processed", result.labels.len() as u64);
        }
        match outcome {
            Ok(result) => set_status(
                sh,
                id,
                JobStatus::Done(Box::new(JobOutput { result, backend_used: backend, wall_s: wall, tag: job.tag.clone() })),
            ),
            Err(e) => set_status(sh, id, JobStatus::Failed(e.to_string())),
        }
    }
}

fn set_status(sh: &Shared, id: JobId, s: JobStatus) {
    sh.status.lock().unwrap().insert(id, s);
    sh.status_cv.notify_all();
}

/// Execute one job; returns the result and the backend that ran it.
fn run_job(
    job: &ClusterJob,
    sh: &Shared,
    router: &Router,
    cfg: &CoordinatorConfig,
) -> (Result<DpcResult, DpcError>, Backend) {
    match &job.payload {
        JobPayload::Points(pts) => {
            let spec = JobSpec::new(pts, job.params.d_cut).dep_algo(job.dep_algo.unwrap_or(cfg.dep_algo));
            let backend = router.resolve(job.backend.unwrap_or(cfg.backend), &spec);
            (run_points_job(pts, &spec, job.params, router, backend), backend)
        }
        JobPayload::Recut(sid) => {
            // Re-cuts are linkage-only and always run in Rust.
            (run_recut_job(*sid, job.params, sh), Backend::TreeExact)
        }
    }
}

/// The unified Steps 1–3 pipeline over whatever engine the router resolved.
fn run_points_job(
    pts: &Arc<PointSet>,
    spec: &JobSpec,
    params: DpcParams,
    router: &Router,
    backend: Backend,
) -> Result<DpcResult, DpcError> {
    session::validate_points(pts)?;
    session::validate_params(&params)?;
    let engine = router.engine(backend);

    let t0 = Instant::now();
    let rho = engine.density(pts, spec)?;
    let density_s = t0.elapsed().as_secs_f64();

    let t1 = Instant::now();
    let dep_ids = engine.dependents(pts, &rho, params.rho_min, spec)?;
    let dep_s = t1.elapsed().as_secs_f64();

    let t2 = Instant::now();
    let link = linkage::single_linkage(pts, &rho, &dep_ids, params);
    let linkage_s = t2.elapsed().as_secs_f64();

    let delta = dep::dependent_distances(pts, &dep_ids);
    Ok(DpcResult {
        rho,
        dep: dep_ids,
        delta,
        labels: link.labels,
        centers: link.centers,
        num_clusters: link.num_clusters,
        num_noise: link.num_noise,
        timings: StepTimings { density_s, dep_s, linkage_s },
    })
}

fn run_recut_job(sid: SessionId, params: DpcParams, sh: &Shared) -> Result<DpcResult, DpcError> {
    let entry = sh
        .sessions
        .lock()
        .unwrap()
        .get(&sid)
        .cloned()
        .ok_or(DpcError::UnknownSession(sid))?;
    let mut out = session::cut_cached(&entry.pts, &entry.rho, &entry.dep, &entry.delta, params);
    // Report the (amortized) build cost in the density slot for visibility.
    out.timings.density_s = entry.build_s;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpc::{Dpc, DepAlgo, DpcParams};
    use crate::geom::PointSet;
    use crate::prng::SplitMix64;

    fn blob_points() -> Arc<PointSet> {
        let mut rng = SplitMix64::new(91);
        let mut coords = Vec::new();
        for c in [(0.0, 0.0), (50.0, 50.0)] {
            for _ in 0..80 {
                coords.push(c.0 + rng.normal());
                coords.push(c.1 + rng.normal());
            }
        }
        Arc::new(PointSet::new(coords, 2))
    }

    fn tree_only_config() -> CoordinatorConfig {
        CoordinatorConfig {
            artifacts_dir: std::path::PathBuf::from("/nonexistent"),
            ..CoordinatorConfig::default()
        }
    }

    #[test]
    fn submit_wait_roundtrip() {
        let coord = Coordinator::start(tree_only_config()).unwrap();
        let job = ClusterJob::new(blob_points(), DpcParams { d_cut: 3.0, rho_min: 0.0, delta_min: 20.0 })
            .tag("two-blobs");
        let out = coord.run_sync(job).unwrap();
        assert_eq!(out.result.num_clusters, 2);
        assert_eq!(out.backend_used, Backend::TreeExact);
        assert_eq!(out.tag, "two-blobs");
        assert!(coord.metrics.counter("jobs_submitted") == 1);
        assert!(coord.metrics.counter("jobs_tree") == 1);
    }

    #[test]
    fn multiple_jobs_complete() {
        let mut cfg = tree_only_config();
        cfg.workers = 2;
        let coord = Coordinator::start(cfg).unwrap();
        let pts = blob_points();
        let ids: Vec<JobId> = (0..6)
            .map(|i| {
                coord.submit(
                    ClusterJob::new(Arc::clone(&pts), DpcParams { d_cut: 3.0, rho_min: 0.0, delta_min: 20.0 })
                        .dep_algo(DepAlgo::ALL[i % 5])
                        .tag(format!("job{i}")),
                )
            })
            .collect();
        for id in ids {
            let out = coord.wait(id).unwrap();
            assert_eq!(out.result.num_clusters, 2);
        }
        assert_eq!(coord.metrics.counter("jobs_submitted"), 6);
    }

    #[test]
    fn unknown_job_is_error() {
        let coord = Coordinator::start(tree_only_config()).unwrap();
        assert!(coord.wait(999).is_err());
    }

    #[test]
    fn status_transitions_to_done() {
        let coord = Coordinator::start(tree_only_config()).unwrap();
        let id = coord.submit(ClusterJob::new(blob_points(), DpcParams { d_cut: 3.0, rho_min: 0.0, delta_min: 20.0 }));
        let _ = coord.wait(id);
        assert!(matches!(coord.status(id), Some(JobStatus::Done(_))));
    }

    #[test]
    fn malformed_job_fails_with_typed_message_not_panic() {
        let coord = Coordinator::start(tree_only_config()).unwrap();
        let empty = Arc::new(PointSet::empty(2));
        let err = coord
            .run_sync(ClusterJob::new(empty, DpcParams { d_cut: 3.0, rho_min: 0.0, delta_min: 20.0 }))
            .unwrap_err();
        assert!(err.contains("empty point set"), "{err}");
        let bad = Arc::new(PointSet::new(vec![0.0, 0.0, 1.0, 1.0], 2));
        let err = coord
            .run_sync(ClusterJob::new(bad, DpcParams { d_cut: -1.0, rho_min: 0.0, delta_min: 20.0 }))
            .unwrap_err();
        assert!(err.contains("d_cut"), "{err}");
    }

    #[test]
    fn session_recut_matches_full_run_and_skips_steps12() {
        let coord = Coordinator::start(tree_only_config()).unwrap();
        let pts = blob_points();
        let sid = coord.open_session(Arc::clone(&pts), 3.0).unwrap();
        for (rho_min, delta_min) in [(0.0, 20.0), (2.0, 10.0), (0.0, f64::INFINITY)] {
            let out = coord
                .wait(coord.submit_recut(sid, rho_min, delta_min).unwrap())
                .unwrap();
            let fresh = Dpc::new(DpcParams { d_cut: 3.0, rho_min, delta_min }).run(&pts).unwrap();
            assert_eq!(out.result.labels, fresh.labels);
            assert_eq!(out.result.rho, fresh.rho);
            assert_eq!(out.result.dep, fresh.dep);
            assert_eq!(out.result.num_clusters, fresh.num_clusters);
            assert_eq!(out.result.num_noise, fresh.num_noise);
        }
        assert_eq!(coord.metrics.counter("sessions_opened"), 1);
        assert_eq!(coord.metrics.counter("recuts_submitted"), 3);
        assert!(coord.close_session(sid));
    }

    #[test]
    fn recut_of_unknown_or_closed_session_is_typed_error() {
        let coord = Coordinator::start(tree_only_config()).unwrap();
        assert!(matches!(coord.submit_recut(42, 0.0, 1.0), Err(DpcError::UnknownSession(42))));
        let sid = coord.open_session(blob_points(), 3.0).unwrap();
        assert!(coord.close_session(sid));
        assert!(!coord.close_session(sid));
        assert!(matches!(coord.submit_recut(sid, 0.0, 1.0), Err(DpcError::UnknownSession(_))));
    }

    #[test]
    fn open_session_validates_input() {
        let coord = Coordinator::start(tree_only_config()).unwrap();
        assert!(matches!(coord.open_session(Arc::new(PointSet::empty(2)), 1.0), Err(DpcError::EmptyInput)));
        assert!(matches!(
            coord.open_session(blob_points(), f64::NAN),
            Err(DpcError::InvalidParam { name: "d_cut", .. })
        ));
    }
}
