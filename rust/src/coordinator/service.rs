//! The coordinator service: job queue + worker pool + router + metrics.
//!
//! Jobs are submitted (non-blocking) and executed by dedicated worker
//! threads; `wait` blocks on a condvar until the job reaches a terminal
//! state. The XLA engine runs Steps 1–2 for routed jobs, with Step 3
//! (single-linkage union-find) always in Rust.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::Instant;

use anyhow::Result;

use crate::dpc::{linkage, Dpc, DpcResult, DepAlgo};
use crate::runtime::XlaService;

use super::config::CoordinatorConfig;
use super::job::{ClusterJob, JobOutput, JobStatus};
use super::metrics::Metrics;
use super::router::{Backend, Router};

pub type JobId = u64;

struct Shared {
    queue: Mutex<VecDeque<(JobId, ClusterJob)>>,
    queue_cv: Condvar,
    status: Mutex<HashMap<JobId, JobStatus>>,
    status_cv: Condvar,
    shutdown: AtomicBool,
}

/// The clustering service. Create with [`Coordinator::start`], submit jobs,
/// `wait` for results, and `shutdown` (also done on drop).
pub struct Coordinator {
    cfg: CoordinatorConfig,
    router: Arc<Router>,
    shared: Arc<Shared>,
    workers: Vec<thread::JoinHandle<()>>,
    next_id: AtomicU64,
    pub metrics: Arc<Metrics>,
}

impl Coordinator {
    /// Start the service. Loads the XLA engine if artifacts are present
    /// (failure to load degrades to tree-only with a warning, never an
    /// error — the tree engine is always available).
    pub fn start(cfg: CoordinatorConfig) -> Result<Self> {
        if cfg.threads > 0 {
            crate::parlay::set_threads(cfg.threads);
        }
        let xla = if cfg.artifacts_dir.join("manifest.txt").exists() {
            match XlaService::start(&cfg.artifacts_dir) {
                Ok(e) => Some(Arc::new(e)),
                Err(e) => {
                    eprintln!("warning: XLA engine unavailable ({e}); tree backend only");
                    None
                }
            }
        } else {
            None
        };
        let router = Arc::new(Router::new(xla, cfg.xla_threshold));
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            queue_cv: Condvar::new(),
            status: Mutex::new(HashMap::new()),
            status_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let metrics = Arc::new(Metrics::new());
        let workers = (0..cfg.workers)
            .map(|w| {
                let sh = Arc::clone(&shared);
                let rt = Arc::clone(&router);
                let mt = Arc::clone(&metrics);
                let cfg = cfg.clone();
                thread::Builder::new()
                    .name(format!("coord-{w}"))
                    .spawn(move || worker_loop(&sh, &rt, &mt, &cfg))
                    .expect("spawn worker")
            })
            .collect();
        Ok(Coordinator { cfg, router, shared, workers, next_id: AtomicU64::new(1), metrics })
    }

    pub fn config(&self) -> &CoordinatorConfig {
        &self.cfg
    }

    pub fn has_xla(&self) -> bool {
        self.router.xla_engine().is_some()
    }

    /// Submit a job; returns immediately.
    pub fn submit(&self, job: ClusterJob) -> JobId {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.shared.status.lock().unwrap().insert(id, JobStatus::Queued);
        self.shared.queue.lock().unwrap().push_back((id, job));
        self.shared.queue_cv.notify_one();
        self.metrics.inc("jobs_submitted");
        id
    }

    /// Current status (non-blocking).
    pub fn status(&self, id: JobId) -> Option<JobStatus> {
        self.shared.status.lock().unwrap().get(&id).cloned()
    }

    /// Block until the job completes; returns the output or the failure
    /// message.
    pub fn wait(&self, id: JobId) -> Result<JobOutput, String> {
        let mut st = self.shared.status.lock().unwrap();
        loop {
            match st.get(&id) {
                None => return Err(format!("unknown job {id}")),
                Some(s) if s.is_terminal() => {
                    return match s.clone() {
                        JobStatus::Done(out) => Ok(*out),
                        JobStatus::Failed(msg) => Err(msg),
                        _ => unreachable!(),
                    };
                }
                _ => st = self.shared.status_cv.wait(st).unwrap(),
            }
        }
    }

    /// Convenience: submit + wait.
    pub fn run_sync(&self, job: ClusterJob) -> Result<JobOutput, String> {
        let id = self.submit(job);
        self.wait(id)
    }

    pub fn shutdown(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.queue_cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_loop(sh: &Shared, router: &Router, metrics: &Metrics, cfg: &CoordinatorConfig) {
    loop {
        let (id, job) = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if sh.shutdown.load(Ordering::Acquire) {
                    return;
                }
                if let Some(item) = q.pop_front() {
                    break item;
                }
                q = sh.queue_cv.wait(q).unwrap();
            }
        };
        set_status(sh, id, JobStatus::Running);
        let t = Instant::now();
        let backend = router.resolve(job.backend.unwrap_or(cfg.backend), job.pts.len(), job.pts.dim());
        let outcome = run_job(&job, backend, router, cfg);
        let wall = t.elapsed().as_secs_f64();
        metrics.inc(&format!("jobs_{}", backend.name()));
        metrics.observe_secs("job_wall", wall);
        metrics.add("points_processed", job.pts.len() as u64);
        match outcome {
            Ok(result) => set_status(
                sh,
                id,
                JobStatus::Done(Box::new(JobOutput { result, backend_used: backend, wall_s: wall, tag: job.tag.clone() })),
            ),
            Err(e) => set_status(sh, id, JobStatus::Failed(e.to_string())),
        }
    }
}

fn set_status(sh: &Shared, id: JobId, s: JobStatus) {
    sh.status.lock().unwrap().insert(id, s);
    sh.status_cv.notify_all();
}

fn run_job(job: &ClusterJob, backend: Backend, router: &Router, cfg: &CoordinatorConfig) -> Result<DpcResult> {
    match backend {
        Backend::XlaBruteForce => {
            let engine = router.xla_engine().expect("router resolved XLA without an engine");
            let t0 = Instant::now();
            let out = engine.run(Arc::clone(&job.pts), job.params.d_cut)?;
            let steps12 = t0.elapsed().as_secs_f64();
            // Noise handling mirrors the tree engine: noise points get no λ.
            let dep: Vec<Option<u32>> = out
                .rho
                .iter()
                .zip(&out.dep)
                .map(|(&r, &d)| if (r as f64) < job.params.rho_min { None } else { d })
                .collect();
            let t1 = Instant::now();
            let link = linkage::single_linkage(&job.pts, &out.rho, &dep, job.params);
            let linkage_s = t1.elapsed().as_secs_f64();
            let delta = crate::dpc::dep::dependent_distances(&job.pts, &dep);
            Ok(DpcResult {
                rho: out.rho,
                dep,
                delta,
                labels: link.labels,
                centers: link.centers,
                num_clusters: link.num_clusters,
                num_noise: link.num_noise,
                timings: crate::dpc::StepTimings { density_s: steps12, dep_s: 0.0, linkage_s },
            })
        }
        Backend::TreeExact | Backend::Auto => {
            let algo: DepAlgo = job.dep_algo.unwrap_or(cfg.dep_algo);
            Ok(Dpc::new(job.params).dep_algo(algo).run(&job.pts))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpc::DpcParams;
    use crate::geom::PointSet;
    use crate::prng::SplitMix64;

    fn blob_points() -> Arc<PointSet> {
        let mut rng = SplitMix64::new(91);
        let mut coords = Vec::new();
        for c in [(0.0, 0.0), (50.0, 50.0)] {
            for _ in 0..80 {
                coords.push(c.0 + rng.normal());
                coords.push(c.1 + rng.normal());
            }
        }
        Arc::new(PointSet::new(coords, 2))
    }

    fn tree_only_config() -> CoordinatorConfig {
        CoordinatorConfig {
            artifacts_dir: std::path::PathBuf::from("/nonexistent"),
            ..CoordinatorConfig::default()
        }
    }

    #[test]
    fn submit_wait_roundtrip() {
        let coord = Coordinator::start(tree_only_config()).unwrap();
        let job = ClusterJob::new(blob_points(), DpcParams { d_cut: 3.0, rho_min: 0.0, delta_min: 20.0 })
            .tag("two-blobs");
        let out = coord.run_sync(job).unwrap();
        assert_eq!(out.result.num_clusters, 2);
        assert_eq!(out.backend_used, Backend::TreeExact);
        assert_eq!(out.tag, "two-blobs");
        assert!(coord.metrics.counter("jobs_submitted") == 1);
        assert!(coord.metrics.counter("jobs_tree") == 1);
    }

    #[test]
    fn multiple_jobs_complete() {
        let mut cfg = tree_only_config();
        cfg.workers = 2;
        let coord = Coordinator::start(cfg).unwrap();
        let pts = blob_points();
        let ids: Vec<JobId> = (0..6)
            .map(|i| {
                coord.submit(
                    ClusterJob::new(Arc::clone(&pts), DpcParams { d_cut: 3.0, rho_min: 0.0, delta_min: 20.0 })
                        .dep_algo(DepAlgo::ALL[i % 5])
                        .tag(format!("job{i}")),
                )
            })
            .collect();
        for id in ids {
            let out = coord.wait(id).unwrap();
            assert_eq!(out.result.num_clusters, 2);
        }
        assert_eq!(coord.metrics.counter("jobs_submitted"), 6);
    }

    #[test]
    fn unknown_job_is_error() {
        let coord = Coordinator::start(tree_only_config()).unwrap();
        assert!(coord.wait(999).is_err());
    }

    #[test]
    fn status_transitions_to_done() {
        let coord = Coordinator::start(tree_only_config()).unwrap();
        let id = coord.submit(ClusterJob::new(blob_points(), DpcParams { d_cut: 3.0, rho_min: 0.0, delta_min: 20.0 }));
        let _ = coord.wait(id);
        assert!(matches!(coord.status(id), Some(JobStatus::Done(_))));
    }
}
