//! The coordinator service: job queue + worker pool + router + metrics +
//! session store.
//!
//! Jobs are submitted (non-blocking) and executed by dedicated worker
//! threads; `wait` blocks on a condvar until the job reaches a terminal
//! state. Both backends are driven through the [`Engine`] trait: the worker
//! runs Step 1 (`density`) and Step 2 (`dependents`) on the resolved engine
//! and Step 3 (single-linkage union-find) always in Rust.
//!
//! Sessions ([`Coordinator::open_session`]) cache a point set's density and
//! full dependency forest so [`Coordinator::submit_recut`] jobs — the
//! decision-graph parameter sweeps of §6.2 — execute only the linkage step.
//!
//! Streams ([`Coordinator::open_stream`]) hold a
//! [`StreamingSession`] so [`Coordinator::submit_ingest`] jobs absorb point
//! batches with amortized-logarithmic index rebuilds instead of from-scratch
//! pipelines; each ingest job reports the post-ingest clustering at its
//! thresholds, byte-identical to a full run on the concatenated points.
//! Ingests into one stream apply in **submission order** (per-stream FIFO
//! tickets — the shared queue alone would let a racing worker apply a later
//! batch first); different streams proceed in parallel across workers.

use std::collections::{HashMap, VecDeque};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar};
use std::thread;
use std::time::Instant;

use anyhow::Result;

use crate::dpc::{dep, linkage, session, DensityModel, DpcParams, DpcResult, StepTimings};
use crate::durability::{
    checkpoint::{self, CheckpointData, SessionState},
    journal::JournalEntry,
    recovery, DynStream, JournalWriter, Manifest,
};
use crate::error::DpcError;
use crate::geom::{Dtype, DynPoints, PointSet, PointStore, Scalar};
use crate::runtime::XlaService;
use crate::sync::{rank, OrderedMutex};

use super::config::CoordinatorConfig;
use super::engine::JobSpec;
use super::job::{ClusterJob, JobOutput, JobPayload, JobStatus};
use super::metrics::Metrics;
use super::router::{Backend, Router};
use super::spec::OpenSpec;

pub type JobId = u64;
pub type SessionId = u64;

/// Cached Steps-1–2 artifacts for one open session: everything a
/// threshold-only re-cut needs.
pub struct SessionEntry {
    pub pts: Arc<PointSet>,
    pub d_cut: f64,
    /// The density model the cached ρ was computed under (re-cuts inherit
    /// it — a threshold sweep never silently changes the density
    /// definition).
    pub density: DensityModel,
    /// ρ per point at `d_cut`.
    pub rho: Vec<u32>,
    /// Full (unthresholded) dependency forest.
    pub dep: Vec<Option<u32>>,
    /// δ for the full forest.
    pub delta: Vec<f64>,
    /// Name of the engine that built the artifacts.
    pub built_by: &'static str,
    /// Wall-clock seconds Step 1 (density) took at build time.
    pub density_s: f64,
    /// Wall-clock seconds Step 2 (dependents + δ) took at build time.
    pub dep_s: f64,
    /// The open's [`OpenSpec::tag`] label, echoed in re-cut job outputs.
    /// In-memory only; recovered sessions carry `"recovered"`.
    pub tag: String,
}

impl SessionEntry {
    /// Total build cost (Steps 1–2) the session amortizes.
    pub fn build_s(&self) -> f64 {
        self.density_s + self.dep_s
    }
}

impl std::fmt::Debug for SessionEntry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SessionEntry")
            .field("points", &self.pts.len())
            .field("d_cut", &self.d_cut)
            .field("density", &self.density)
            .field("built_by", &self.built_by)
            .field("tag", &self.tag)
            .finish_non_exhaustive()
    }
}

/// An open streaming session plus its immutable radius (readable without
/// taking the session lock, so submitting never blocks behind a running
/// ingest).
pub struct StreamEntry {
    pub d_cut: f64,
    /// The stream's density model (immutable, like the radius — readable
    /// without the session lock).
    pub density: DensityModel,
    /// The stream's coordinate precision (immutable; batches must match
    /// or `submit_ingest_dyn` fails with [`DpcError::DtypeMismatch`]).
    pub dtype: Dtype,
    /// The open's [`OpenSpec::tag`] label, echoed in ingest job outputs.
    /// In-memory only; recovered streams carry `"recovered"`.
    pub tag: String,
    pub session: OrderedMutex<DynStream, { rank::STREAM_STATE }>,
    /// FIFO ingest tickets, issued under this lock *around* the queue push
    /// so ticket order equals queue order; workers wait for their ticket
    /// before applying, which makes batches land in submission order
    /// regardless of worker scheduling. `closed` unblocks waiters when the
    /// stream is dropped mid-burst (their predecessors may never bump).
    tickets: OrderedMutex<TicketState, { rank::STREAM_TICKETS }>,
    turn: Condvar,
}

impl std::fmt::Debug for StreamEntry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("StreamEntry")
            .field("d_cut", &self.d_cut)
            .field("density", &self.density)
            .field("dtype", &self.dtype)
            .field("tag", &self.tag)
            .finish_non_exhaustive()
    }
}

#[derive(Clone, Copy, Default)]
struct TicketState {
    next: u64,
    applied: u64,
    closed: bool,
}

struct Shared {
    queue: OrderedMutex<VecDeque<(JobId, ClusterJob)>, { rank::JOB_QUEUE }>,
    queue_cv: Condvar,
    status: OrderedMutex<HashMap<JobId, JobStatus>, { rank::JOB_STATUS }>,
    status_cv: Condvar,
    shutdown: AtomicBool,
    sessions: OrderedMutex<HashMap<SessionId, Arc<SessionEntry>>, { rank::SESSION_REGISTRY }>,
    streams: OrderedMutex<HashMap<SessionId, Arc<StreamEntry>>, { rank::STREAM_REGISTRY }>,
    /// Jobs submitted but not yet terminal (queued + running). The
    /// admission gate ([`Coordinator::try_submit`] and the gated
    /// `submit_recut`/`submit_ingest` paths) bounds this at
    /// `CoordinatorConfig::max_inflight_jobs`; workers decrement as jobs
    /// reach a terminal status.
    inflight: AtomicU64,
}

/// The write-ahead half of `--durable` serve mode. Lock ordering: the
/// journal lock is the OUTERMOST coordinator state lock
/// ([`rank::JOURNAL`]) — taken before any ticket, stream-map, or
/// session-map lock and never after them — so journal order always equals
/// ticket/application order, and [`Coordinator::checkpoint_now`] can
/// freeze the command stream by holding it alone. The ordering is
/// machine-checked: every lock here carries its [`rank`] and debug builds
/// abort on any out-of-order acquisition.
struct DurableLog {
    dir: PathBuf,
    journal: OrderedMutex<JournalWriter, { rank::JOURNAL }>,
}

/// The clustering service. Create with [`Coordinator::start`], submit jobs,
/// `wait` for results, and `shutdown` (also done on drop).
pub struct Coordinator {
    cfg: CoordinatorConfig,
    router: Arc<Router>,
    shared: Arc<Shared>,
    workers: Vec<thread::JoinHandle<()>>,
    next_id: AtomicU64,
    next_session_id: AtomicU64,
    durable: Option<DurableLog>,
    pub metrics: Arc<Metrics>,
}

impl std::fmt::Debug for Coordinator {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Coordinator")
            .field("workers", &self.workers.len())
            .field("durable", &self.durable.is_some())
            .field("has_xla", &self.router.has_xla())
            .finish_non_exhaustive()
    }
}

impl Coordinator {
    /// Start the service. Loads the XLA engine if artifacts are present
    /// (failure to load degrades to tree-only with a warning, never an
    /// error — the tree engine is always available).
    pub fn start(cfg: CoordinatorConfig) -> Result<Self> {
        if cfg.threads > 0 {
            crate::parlay::set_threads(cfg.threads);
        }
        let xla = if cfg.artifacts_dir.join("manifest.txt").exists() {
            match XlaService::start(&cfg.artifacts_dir) {
                Ok(e) => Some(Arc::new(e)),
                Err(e) => {
                    eprintln!("warning: XLA engine unavailable ({e}); tree backend only");
                    None
                }
            }
        } else {
            None
        };
        let router = Arc::new(Router::new(xla, cfg.xla_threshold));

        // Durable serve: recover (or initialize) the journal + checkpoint
        // directory and seed the session/stream maps with the restored
        // state before any worker can observe them.
        let mut sessions: HashMap<SessionId, Arc<SessionEntry>> = HashMap::new();
        let mut streams: HashMap<SessionId, Arc<StreamEntry>> = HashMap::new();
        let mut first_session_id = 1u64;
        let durable = match &cfg.durable_dir {
            None => None,
            Some(dir) => {
                let rec = recovery::recover(dir, cfg.fsync_every, cfg.journal_rotate_bytes)?;
                if rec.report.replayed > 0 || rec.report.torn_bytes > 0 || rec.report.checkpoint_seq > 0 {
                    eprintln!(
                        "durable recovery: checkpoint {} + {} journal entries replayed ({} skipped) across {} segments, {} torn bytes truncated",
                        rec.report.checkpoint_seq, rec.report.replayed, rec.report.skipped, rec.report.segments, rec.report.torn_bytes
                    );
                }
                // Both precisions come back first-class: the stream map
                // holds the runtime union, so a recovered f32 stream keeps
                // ingesting f32 batches after the restart.
                for (id, ds) in rec.streams {
                    streams.insert(
                        id,
                        Arc::new(StreamEntry {
                            d_cut: ds.d_cut(),
                            density: ds.density_model(),
                            dtype: ds.dtype(),
                            tag: "recovered".to_string(),
                            session: OrderedMutex::new(ds),
                            tickets: OrderedMutex::new(TicketState::default()),
                            turn: Condvar::new(),
                        }),
                    );
                }
                for s in rec.sessions {
                    sessions.insert(
                        s.id,
                        Arc::new(SessionEntry {
                            pts: Arc::new(s.pts),
                            d_cut: s.d_cut,
                            density: s.density,
                            rho: s.rho,
                            dep: s.dep,
                            delta: s.delta,
                            built_by: match s.built_by.as_str() {
                                "tree" => "tree",
                                "xla" => "xla",
                                "replay" => "replay",
                                _ => "recovered",
                            },
                            density_s: s.density_secs,
                            dep_s: s.dep_secs,
                            tag: "recovered".to_string(),
                        }),
                    );
                }
                first_session_id = rec.next_session_id;
                Some(DurableLog { dir: dir.clone(), journal: OrderedMutex::new(rec.writer) })
            }
        };

        let shared = Arc::new(Shared {
            queue: OrderedMutex::new(VecDeque::new()),
            queue_cv: Condvar::new(),
            status: OrderedMutex::new(HashMap::new()),
            status_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            sessions: OrderedMutex::new(sessions),
            streams: OrderedMutex::new(streams),
            inflight: AtomicU64::new(0),
        });
        let metrics = Arc::new(Metrics::new());
        let workers = (0..cfg.workers)
            .map(|w| {
                let sh = Arc::clone(&shared);
                let rt = Arc::clone(&router);
                let mt = Arc::clone(&metrics);
                let cfg = cfg.clone();
                thread::Builder::new()
                    .name(format!("coord-{w}"))
                    .spawn(move || worker_loop(&sh, &rt, &mt, &cfg))
                    // lint: allow(panic-surface) — thread spawn fails only on
                    // resource exhaustion at startup; no caller can proceed.
                    .expect("spawn worker")
            })
            .collect();
        Ok(Coordinator {
            cfg,
            router,
            shared,
            workers,
            next_id: AtomicU64::new(1),
            next_session_id: AtomicU64::new(first_session_id),
            durable,
            metrics,
        })
    }

    pub fn config(&self) -> &CoordinatorConfig {
        &self.cfg
    }

    pub fn has_xla(&self) -> bool {
        self.router.has_xla()
    }

    /// Whether this coordinator write-ahead-journals its commands.
    pub fn is_durable(&self) -> bool {
        self.durable.is_some()
    }

    /// Append to the write-ahead journal (no-op when not durable). Called
    /// BEFORE the in-memory state change is published, so a command is
    /// never acknowledged without a durable record.
    fn journal_append(&self, entry: &JournalEntry) -> Result<(), DpcError> {
        if let Some(d) = &self.durable {
            d.journal.lock().append(entry)?;
        }
        Ok(())
    }

    /// Submit a job; returns immediately. Unbounded — the admission gate
    /// lives in [`Coordinator::try_submit`] and the `submit_recut` /
    /// `submit_ingest` paths; this raw entry point always queues (tests,
    /// embedded batch drivers).
    pub fn submit(&self, job: ClusterJob) -> JobId {
        // relaxed: pure id allocation — uniqueness is all that matters.
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.shared.inflight.fetch_add(1, Ordering::AcqRel);
        self.shared.status.lock().insert(id, JobStatus::Queued);
        self.shared.queue.lock().push_back((id, job));
        self.shared.queue_cv.notify_one();
        self.metrics.inc("jobs_submitted");
        id
    }

    /// Jobs submitted but not yet terminal (queued + running).
    pub fn inflight_jobs(&self) -> u64 {
        self.shared.inflight.load(Ordering::Acquire)
    }

    /// Reserve an in-flight slot against `max_inflight_jobs` (0 = no
    /// limit). A CAS loop so concurrent admitters can never overshoot the
    /// limit; the slot is released when the job goes terminal, so a
    /// caller that reserves MUST enqueue (or call `release_slot` on an
    /// abandoned path).
    fn admit_job(&self) -> Result<(), DpcError> {
        let limit = self.cfg.max_inflight_jobs;
        if limit == 0 {
            self.shared.inflight.fetch_add(1, Ordering::AcqRel);
            return Ok(());
        }
        let mut cur = self.shared.inflight.load(Ordering::Acquire);
        loop {
            if cur >= limit {
                self.metrics.inc("jobs_rejected_backpressure");
                return Err(DpcError::Backpressure { in_flight: cur, limit });
            }
            match self.shared.inflight.compare_exchange_weak(cur, cur + 1, Ordering::AcqRel, Ordering::Acquire) {
                Ok(_) => return Ok(()),
                Err(c) => cur = c,
            }
        }
    }

    fn release_slot(&self) {
        self.shared.inflight.fetch_sub(1, Ordering::AcqRel);
    }

    /// Enqueue a job whose slot [`Coordinator::admit_job`] already
    /// reserved (keeps `submit`'s unconditional increment from double
    /// counting).
    fn submit_admitted(&self, job: ClusterJob) -> JobId {
        // relaxed: pure id allocation — uniqueness is all that matters.
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.shared.status.lock().insert(id, JobStatus::Queued);
        self.shared.queue.lock().push_back((id, job));
        self.shared.queue_cv.notify_one();
        self.metrics.inc("jobs_submitted");
        id
    }

    /// [`Coordinator::submit`] behind the admission gate: fails with
    /// [`DpcError::Backpressure`] instead of queueing once
    /// `max_inflight_jobs` jobs are queued or running. The serve surfaces
    /// submit through this so a traffic burst degrades into explicit
    /// `Busy` responses rather than an unbounded queue.
    pub fn try_submit(&self, job: ClusterJob) -> Result<JobId, DpcError> {
        self.admit_job()?;
        Ok(self.submit_admitted(job))
    }

    /// Open a session described by an [`OpenSpec`] with a points source:
    /// validate the input, run Steps 1–2 once through the routed engine,
    /// and cache the artifacts for threshold-only re-cuts. Synchronous —
    /// the build is the expensive part the session exists to amortize, so
    /// callers should see its cost exactly once.
    pub fn open_session(&self, spec: OpenSpec) -> Result<SessionId, DpcError> {
        spec.validate()?;
        let (pts, d_cut, density, tag) = spec.into_points()?;
        session::validate_points(&pts)?;
        // The payload shares the session store's coordinate buffer (a
        // refcount bump, no copy).
        let payload = DynPoints::F64((*pts).clone());
        let spec = JobSpec::from_payload(&payload, d_cut).dep_algo(self.cfg.dep_algo).density_model(density);
        let backend = self.router.resolve(self.cfg.backend, &spec);
        let engine = self.router.engine(backend);
        let t = Instant::now();
        let rho = engine.density(&payload, &spec)?;
        let density_s = t.elapsed().as_secs_f64();
        let t = Instant::now();
        // rho_min = 0: the full forest, so any later threshold is a mask.
        let dep = engine.dependents(&payload, &rho, 0.0, &spec)?;
        let delta = dep::dependent_distances(&pts, &dep);
        let dep_s = t.elapsed().as_secs_f64();
        let entry = Arc::new(SessionEntry {
            pts,
            d_cut,
            density,
            rho,
            dep,
            delta,
            built_by: engine.name(),
            density_s,
            dep_s,
            tag,
        });
        // relaxed: pure id allocation — uniqueness is all that matters.
        let id = self.next_session_id.fetch_add(1, Ordering::Relaxed);
        // WAL before publish: replay recomputes the same artifacts from
        // the logged inputs (the pipeline is deterministic).
        self.journal_append(&JournalEntry::OpenSession { session: id, d_cut, density, pts: payload })?;
        self.shared.sessions.lock().insert(id, entry);
        self.metrics.inc("sessions_opened");
        Ok(id)
    }

    /// Look up an open session's cached artifacts.
    pub fn session(&self, id: SessionId) -> Option<Arc<SessionEntry>> {
        self.shared.sessions.lock().get(&id).cloned()
    }

    /// Every open session id (serve admission seeds its registry from
    /// this after a durable recovery).
    pub fn session_ids(&self) -> Vec<SessionId> {
        self.shared.sessions.lock().keys().copied().collect()
    }

    /// Every open stream id.
    pub fn stream_ids(&self) -> Vec<SessionId> {
        self.shared.streams.lock().keys().copied().collect()
    }

    /// Submit a linkage-only re-cut of an open session at new thresholds.
    /// Gated by `max_inflight_jobs`: at the limit this fails with
    /// [`DpcError::Backpressure`] instead of queueing.
    pub fn submit_recut(&self, id: SessionId, rho_min: f64, delta_min: f64) -> Result<JobId, DpcError> {
        session::validate_thresholds(rho_min, delta_min)?;
        let entry = self.session(id).ok_or(DpcError::UnknownSession(id))?;
        let params =
            DpcParams { d_cut: entry.d_cut, rho_min, delta_min, density: entry.density, ..DpcParams::default() };
        let tag = if entry.tag.is_empty() { format!("recut:{id}") } else { entry.tag.clone() };
        self.admit_job()?;
        // Audit-only entry: replay rebuilds the same cached artifacts from
        // the session's OpenSession record, so a recut has nothing to redo.
        if let Err(e) = self.journal_append(&JournalEntry::Recut { session: id, rho_min, delta_min }) {
            self.release_slot();
            return Err(e);
        }
        let job = ClusterJob::recut(id, params).tag(tag);
        self.metrics.inc("recuts_submitted");
        Ok(self.submit_admitted(job))
    }

    /// Drop a session's cached artifacts. Closing an id that was never
    /// opened (or already closed) is a typed
    /// [`DpcError::UnknownSession`]; re-cuts already dequeued keep their
    /// `Arc` and complete.
    pub fn close_session(&self, id: SessionId) -> Result<(), DpcError> {
        // Journal lock (outermost) before the map lock; the entry is
        // logged only for a session that actually existed.
        let mut journal = self.durable.as_ref().map(|d| d.journal.lock());
        let mut sessions = self.shared.sessions.lock();
        if !sessions.contains_key(&id) {
            return Err(DpcError::UnknownSession(id));
        }
        if let Some(j) = journal.as_deref_mut() {
            if let Err(e) = j.append(&JournalEntry::CloseSession { session: id }) {
                // Degrade durability, not availability: the close applies
                // in memory; a crash before the next checkpoint resurrects
                // the session, which a client can simply re-close.
                eprintln!("warning: journaling close-session {id} failed: {e}");
            }
        }
        sessions.remove(&id);
        self.metrics.inc("sessions_closed");
        Ok(())
    }

    /// Open a streaming session described by an [`OpenSpec`] with a
    /// dimension source: subsequent [`Coordinator::submit_ingest`] jobs
    /// grow it batch by batch at the spec's fixed radius and density
    /// model. Stream ids share the session id namespace but not the
    /// session store.
    pub fn open_stream(&self, spec: OpenSpec) -> Result<SessionId, DpcError> {
        spec.validate()?;
        let (dim, d_cut, density, dtype, tag) = spec.into_dim()?;
        let s = DynStream::new_with_model(dtype, dim, d_cut, density)?;
        // relaxed: pure id allocation — uniqueness is all that matters.
        let id = self.next_session_id.fetch_add(1, Ordering::Relaxed);
        self.journal_append(&JournalEntry::OpenStream {
            stream: id,
            dim: dim as u32,
            dtype,
            d_cut,
            density,
        })?;
        self.shared.streams.lock().insert(
            id,
            Arc::new(StreamEntry {
                d_cut,
                density,
                dtype,
                tag,
                session: OrderedMutex::new(s),
                tickets: OrderedMutex::new(TicketState::default()),
                turn: Condvar::new(),
            }),
        );
        self.metrics.inc("streams_opened");
        Ok(id)
    }

    /// Look up an open stream.
    pub fn stream(&self, id: SessionId) -> Option<Arc<StreamEntry>> {
        self.shared.streams.lock().get(&id).cloned()
    }

    /// Submit a batch ingest into an open stream. The job repairs the
    /// stream's (ρ, λ, δ) artifacts and reports the post-ingest clustering
    /// at the given thresholds — byte-identical to a from-scratch run on
    /// the concatenated points. Ingests into one stream apply in
    /// submission order; note a worker that dequeues a not-yet-eligible
    /// ingest parks until its turn, so bursting many ingests into a single
    /// stream can occupy up to `workers − 1` threads — bound bursts (or
    /// wait per batch) when sharing a coordinator with latency-sensitive
    /// jobs.
    pub fn submit_ingest(
        &self,
        id: SessionId,
        batch: Arc<PointSet>,
        rho_min: f64,
        delta_min: f64,
    ) -> Result<JobId, DpcError> {
        // The store share is a refcount bump, not a copy.
        self.submit_ingest_dyn(id, DynPoints::F64((*batch).clone()), rho_min, delta_min)
    }

    /// [`Coordinator::submit_ingest`] over a runtime-tagged batch: the
    /// batch's precision must match the stream's (checked BEFORE the WAL
    /// append — a mismatch is a typed [`DpcError::DtypeMismatch`] at
    /// submit time, never a journaled entry that fails on every replay).
    pub fn submit_ingest_dyn(
        &self,
        id: SessionId,
        batch: DynPoints,
        rho_min: f64,
        delta_min: f64,
    ) -> Result<JobId, DpcError> {
        session::validate_thresholds(rho_min, delta_min)?;
        // Reject poisoned batches BEFORE the WAL append below: a journaled
        // batch is replayed on recovery, and a non-finite coordinate that
        // got past this point would re-panic the stream engine on every
        // restart. (Stream-level `ingest` re-validates, but by then the
        // entry is durable.)
        batch.validate_finite()?;
        let entry = self.stream(id).ok_or(DpcError::UnknownSession(id))?;
        if batch.dtype() != entry.dtype {
            return Err(DpcError::DtypeMismatch {
                expected: entry.dtype.name(),
                got: batch.dtype().name(),
            });
        }
        let params =
            DpcParams { d_cut: entry.d_cut, rho_min, delta_min, density: entry.density, ..DpcParams::default() };
        let tag = if entry.tag.is_empty() { format!("ingest:{id}") } else { entry.tag.clone() };
        self.admit_job()?;
        // WAL first, and hold the journal lock (outermost) across ticket
        // issuance and the queue push: journal order == ticket order ==
        // application order for every stream, which is exactly what replay
        // reproduces. The batch share is a refcount bump, not a copy.
        let mut journal = self.durable.as_ref().map(|d| d.journal.lock());
        if let Some(j) = journal.as_deref_mut() {
            if let Err(e) = j.append(&JournalEntry::Ingest {
                stream: id,
                rho_min,
                delta_min,
                batch: batch.clone(),
            }) {
                self.release_slot();
                return Err(e);
            }
        }
        // Issue the ticket and enqueue under the ticket lock, so ticket
        // order always equals queue order for this stream.
        let mut tickets = entry.tickets.lock();
        let seq = tickets.next;
        tickets.next += 1;
        let job = ClusterJob::ingest(id, batch, seq, params).tag(tag);
        self.metrics.inc("ingests_submitted");
        let job_id = self.submit_admitted(job);
        drop(tickets);
        drop(journal);
        Ok(job_id)
    }

    /// Drop an open stream. Closing an id that was never opened (or
    /// already closed) is a typed [`DpcError::UnknownSession`]. Ingests
    /// already dequeued keep their `Arc` and may still complete in ticket
    /// order; ones that look the stream up after the close fail with
    /// [`DpcError::UnknownSession`] — and the close wakes ticket waiters so
    /// a job stranded behind such a failed predecessor bails out instead of
    /// deadlocking the worker pool.
    pub fn close_stream(&self, id: SessionId) -> Result<(), DpcError> {
        // Journal lock (outermost) before the map and ticket locks.
        let mut journal = self.durable.as_ref().map(|d| d.journal.lock());
        let removed = self.shared.streams.lock().remove(&id);
        match removed {
            Some(entry) => {
                if let Some(j) = journal.as_deref_mut() {
                    if let Err(e) = j.append(&JournalEntry::CloseStream { stream: id }) {
                        eprintln!("warning: journaling close-stream {id} failed: {e}");
                    }
                }
                let mut tickets = entry.tickets.lock();
                tickets.closed = true;
                entry.turn.notify_all();
                drop(tickets);
                self.metrics.inc("streams_closed");
                Ok(())
            }
            None => Err(DpcError::UnknownSession(id)),
        }
    }

    /// Take a checkpoint NOW: freeze the command stream (journal lock),
    /// wait for every issued ingest ticket to apply, export all stream and
    /// session state, and atomically flip the manifest to the new
    /// snapshot. Returns the new manifest. Requires `--durable`.
    ///
    /// Quiescing terminates because the journal lock blocks new ticket
    /// issuance while workers (which never take the journal lock) drain
    /// the already-queued ingests.
    pub fn checkpoint_now(&self) -> Result<Manifest, DpcError> {
        let Some(d) = &self.durable else {
            return Err(DpcError::MissingStage { need: "durable serve (--durable)", call: "checkpoint" });
        };
        let mut journal = d.journal.lock();
        let streams: Vec<(SessionId, Arc<StreamEntry>)> =
            self.shared.streams.lock().iter().map(|(k, v)| (*k, Arc::clone(v))).collect();
        let mut stream_states = Vec::with_capacity(streams.len());
        for (sid, entry) in &streams {
            let mut tickets = entry.tickets.lock();
            while tickets.applied != tickets.next {
                tickets = tickets.wait(&entry.turn);
            }
            drop(tickets);
            stream_states.push((*sid, entry.session.lock().export_state()));
        }
        let sessions: Vec<SessionState> = self
            .shared
            .sessions
            .lock()
            .iter()
            .map(|(id, e)| SessionState {
                id: *id,
                d_cut: e.d_cut,
                density: e.density,
                pts: (*e.pts).clone(),
                rho: e.rho.clone(),
                dep: e.dep.clone(),
                delta: e.delta.clone(),
                built_by: e.built_by.to_string(),
                density_secs: e.density_s,
                dep_secs: e.dep_s,
            })
            .collect();
        let data = CheckpointData { streams: stream_states, sessions };
        // relaxed: reading our own id allocator; the journal lock already
        // froze every path that could bump it.
        //
        // `write` also runs both GC sweeps after the manifest flip:
        // checkpoint files outside the newest `checkpoint_retain` roots
        // (and their delta references), and whole journal segments below
        // the new replay horizon — this is what keeps disk use bounded.
        let m = checkpoint::write(
            &d.dir,
            &mut journal,
            &data,
            self.next_session_id.load(Ordering::Relaxed),
            self.cfg.checkpoint_retain,
        )?;
        self.metrics.inc("checkpoints_taken");
        Ok(m)
    }

    /// Current status (non-blocking).
    pub fn status(&self, id: JobId) -> Option<JobStatus> {
        self.shared.status.lock().get(&id).cloned()
    }

    /// Block until the job completes; returns the output or the failure
    /// message.
    pub fn wait(&self, id: JobId) -> Result<JobOutput, String> {
        let mut st = self.shared.status.lock();
        loop {
            match st.get(&id) {
                None => return Err(format!("unknown job {id}")),
                Some(s) if s.is_terminal() => {
                    return match s.clone() {
                        JobStatus::Done(out) => Ok(*out),
                        JobStatus::Failed(msg) => Err(msg),
                        // lint: allow(panic-surface) — is_terminal() just
                        // matched Done/Failed; no third terminal state exists.
                        _ => unreachable!(),
                    };
                }
                _ => st = st.wait(&self.shared.status_cv),
            }
        }
    }

    /// Convenience: submit + wait.
    pub fn run_sync(&self, job: ClusterJob) -> Result<JobOutput, String> {
        let id = self.submit(job);
        self.wait(id)
    }

    pub fn shutdown(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.queue_cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_loop(sh: &Shared, router: &Router, metrics: &Metrics, cfg: &CoordinatorConfig) {
    loop {
        let (id, job) = {
            let mut q = sh.queue.lock();
            loop {
                if sh.shutdown.load(Ordering::Acquire) {
                    return;
                }
                if let Some(item) = q.pop_front() {
                    break item;
                }
                q = q.wait(&sh.queue_cv);
            }
        };
        set_status(sh, id, JobStatus::Running);
        let t = Instant::now();
        let (outcome, backend) = run_job(&job, sh, router, cfg);
        let wall = t.elapsed().as_secs_f64();
        metrics.inc(&format!("jobs_{}", backend.name()));
        metrics.observe_secs("job_wall", wall);
        if let Ok(result) = &outcome {
            metrics.add("points_processed", result.labels.len() as u64);
        }
        match outcome {
            Ok(result) => set_status(
                sh,
                id,
                JobStatus::Done(Box::new(JobOutput { result, backend_used: backend, wall_s: wall, tag: job.tag.clone() })),
            ),
            Err(e) => set_status(sh, id, JobStatus::Failed(e.to_string())),
        }
        // Terminal status is visible; free the admission slot so a caller
        // parked on Backpressure can get in. Decrement AFTER set_status so
        // `inflight` never undercounts live work.
        sh.inflight.fetch_sub(1, Ordering::AcqRel);
    }
}

fn set_status(sh: &Shared, id: JobId, s: JobStatus) {
    sh.status.lock().insert(id, s);
    sh.status_cv.notify_all();
}

/// Execute one job; returns the result and the backend that ran it.
fn run_job(
    job: &ClusterJob,
    sh: &Shared,
    router: &Router,
    cfg: &CoordinatorConfig,
) -> (Result<DpcResult, DpcError>, Backend) {
    match &job.payload {
        JobPayload::Points(pts) => {
            let spec = JobSpec::from_payload(pts, job.params.d_cut)
                .dep_algo(job.dep_algo.unwrap_or(cfg.dep_algo))
                .density_model(job.params.density);
            let backend = router.resolve(job.backend.unwrap_or(cfg.backend), &spec);
            (run_points_job(pts, &spec, job.params, router, backend), backend)
        }
        JobPayload::Recut(sid) => {
            // Re-cuts are linkage-only and always run in Rust.
            (run_recut_job(*sid, job.params, sh), Backend::TreeExact)
        }
        JobPayload::Ingest { stream, batch, seq } => {
            // Ingests repair tree-backed artifacts and always run in Rust.
            (run_ingest_job(*stream, batch, *seq, job.params, sh), Backend::TreeExact)
        }
    }
}

/// The unified Steps 1–3 pipeline over whatever engine the router resolved.
/// Dispatches on the payload's precision tag, then runs the generic
/// pipeline — Steps 1–2 through the [`super::engine::Engine`] trait, Step 3
/// (union-find linkage) always in Rust.
fn run_points_job(
    pts: &DynPoints,
    spec: &JobSpec,
    params: DpcParams,
    router: &Router,
    backend: Backend,
) -> Result<DpcResult, DpcError> {
    match pts {
        DynPoints::F32(p) => run_points_pipeline(p, pts, spec, params, router, backend),
        DynPoints::F64(p) => run_points_pipeline(p, pts, spec, params, router, backend),
    }
}

fn run_points_pipeline<S: Scalar>(
    store: &PointStore<S>,
    payload: &DynPoints,
    spec: &JobSpec,
    params: DpcParams,
    router: &Router,
    backend: Backend,
) -> Result<DpcResult, DpcError> {
    session::validate_points(store)?;
    session::validate_params(&params)?;
    let engine = router.engine(backend);

    let t0 = Instant::now();
    let rho = engine.density(payload, spec)?;
    let density_s = t0.elapsed().as_secs_f64();

    let t1 = Instant::now();
    let dep_ids = engine.dependents(payload, &rho, params.rho_min, spec)?;
    let dep_s = t1.elapsed().as_secs_f64();

    let t2 = Instant::now();
    let link = linkage::single_linkage(store, &rho, &dep_ids, params);
    let linkage_s = t2.elapsed().as_secs_f64();

    let delta = dep::dependent_distances(store, &dep_ids);
    Ok(DpcResult {
        rho,
        dep: dep_ids,
        delta,
        labels: link.labels,
        centers: link.centers,
        num_clusters: link.num_clusters,
        num_noise: link.num_noise,
        timings: StepTimings { density_s, dep_s, linkage_s },
    })
}

fn run_recut_job(sid: SessionId, params: DpcParams, sh: &Shared) -> Result<DpcResult, DpcError> {
    let entry = sh
        .sessions
        .lock()
        .get(&sid)
        .cloned()
        .ok_or(DpcError::UnknownSession(sid))?;
    let mut out = session::cut_cached(&entry.pts, &entry.rho, &entry.dep, &entry.delta, params);
    // Report the cached stages' (amortized) build costs in their own slots,
    // so Table-3-style per-step accounting stays truthful on recut paths.
    out.timings.density_s = entry.density_s;
    out.timings.dep_s = entry.dep_s;
    Ok(out)
}

fn run_ingest_job(
    sid: SessionId,
    batch: &DynPoints,
    seq: u64,
    params: DpcParams,
    sh: &Shared,
) -> Result<DpcResult, DpcError> {
    let entry = sh
        .streams
        .lock()
        .get(&sid)
        .cloned()
        .ok_or(DpcError::UnknownSession(sid))?;
    // Wait for this job's turn: the shared queue is FIFO and tickets are
    // issued in queue order, so every earlier ticket is already running on
    // some worker (or done) — the wait always makes progress. The one
    // exception is a closed stream, where an earlier job may have failed
    // its lookup without ever bumping: `closed` bails waiters out.
    {
        let mut tickets = entry.tickets.lock();
        while tickets.applied != seq {
            if tickets.closed {
                return Err(DpcError::UnknownSession(sid));
            }
            tickets = tickets.wait(&entry.turn);
        }
    }
    let result = {
        let mut stream = entry.session.lock();
        match stream.ingest(batch) {
            Ok(()) => stream.cut(params.rho_min, params.delta_min),
            Err(e) => Err(e),
        }
    };
    // Bump even on failure so later tickets are never stranded.
    let mut tickets = entry.tickets.lock();
    tickets.applied += 1;
    entry.turn.notify_all();
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpc::{Dpc, DepAlgo, DpcParams};
    use crate::geom::PointSet;
    use crate::prng::SplitMix64;

    fn blob_points() -> Arc<PointSet> {
        let mut rng = SplitMix64::new(91);
        let mut coords = Vec::new();
        for c in [(0.0, 0.0), (50.0, 50.0)] {
            for _ in 0..80 {
                coords.push(c.0 + rng.normal());
                coords.push(c.1 + rng.normal());
            }
        }
        Arc::new(PointSet::new(coords, 2))
    }

    fn tree_only_config() -> CoordinatorConfig {
        CoordinatorConfig {
            artifacts_dir: std::path::PathBuf::from("/nonexistent"),
            ..CoordinatorConfig::default()
        }
    }

    #[test]
    fn submit_wait_roundtrip() {
        let coord = Coordinator::start(tree_only_config()).unwrap();
        let job = ClusterJob::new(blob_points(), DpcParams { d_cut: 3.0, rho_min: 0.0, delta_min: 20.0, ..DpcParams::default() })
            .tag("two-blobs");
        let out = coord.run_sync(job).unwrap();
        assert_eq!(out.result.num_clusters, 2);
        assert_eq!(out.backend_used, Backend::TreeExact);
        assert_eq!(out.tag, "two-blobs");
        assert!(coord.metrics.counter("jobs_submitted") == 1);
        assert!(coord.metrics.counter("jobs_tree") == 1);
    }

    #[test]
    fn multiple_jobs_complete() {
        let mut cfg = tree_only_config();
        cfg.workers = 2;
        let coord = Coordinator::start(cfg).unwrap();
        let pts = blob_points();
        let ids: Vec<JobId> = (0..6)
            .map(|i| {
                coord.submit(
                    ClusterJob::new(Arc::clone(&pts), DpcParams { d_cut: 3.0, rho_min: 0.0, delta_min: 20.0, ..DpcParams::default() })
                        .dep_algo(DepAlgo::ALL[i % 5])
                        .tag(format!("job{i}")),
                )
            })
            .collect();
        for id in ids {
            let out = coord.wait(id).unwrap();
            assert_eq!(out.result.num_clusters, 2);
        }
        assert_eq!(coord.metrics.counter("jobs_submitted"), 6);
    }

    #[test]
    fn f32_jobs_run_through_the_same_queue() {
        let coord = Coordinator::start(tree_only_config()).unwrap();
        let pts64 = blob_points();
        let pts32 = Arc::new(PointStore::<f32>::cast_from_f64(&pts64));
        let params = DpcParams {
            d_cut: 3.0,
            rho_min: 0.0,
            delta_min: 20.0,
            dtype: crate::geom::Dtype::F32,
            ..DpcParams::default()
        };
        let out = coord
            .run_sync(ClusterJob::new_f32(Arc::clone(&pts32), params).tag("two-blobs-f32"))
            .unwrap();
        assert_eq!(out.result.num_clusters, 2);
        assert_eq!(out.backend_used, Backend::TreeExact);
        // Identical to the direct generic pipeline on the same f32 store.
        let fresh = Dpc::new(params).run(&*pts32).unwrap();
        assert_eq!(out.result.rho, fresh.rho);
        assert_eq!(out.result.dep, fresh.dep);
        assert_eq!(out.result.labels, fresh.labels);
    }

    #[test]
    fn unknown_job_is_error() {
        let coord = Coordinator::start(tree_only_config()).unwrap();
        assert!(coord.wait(999).is_err());
    }

    #[test]
    fn status_transitions_to_done() {
        let coord = Coordinator::start(tree_only_config()).unwrap();
        let id = coord.submit(ClusterJob::new(blob_points(), DpcParams { d_cut: 3.0, rho_min: 0.0, delta_min: 20.0, ..DpcParams::default() }));
        let _ = coord.wait(id);
        assert!(matches!(coord.status(id), Some(JobStatus::Done(_))));
    }

    #[test]
    fn malformed_job_fails_with_typed_message_not_panic() {
        let coord = Coordinator::start(tree_only_config()).unwrap();
        let empty = Arc::new(PointSet::empty(2));
        let err = coord
            .run_sync(ClusterJob::new(empty, DpcParams { d_cut: 3.0, rho_min: 0.0, delta_min: 20.0, ..DpcParams::default() }))
            .unwrap_err();
        assert!(err.contains("empty point set"), "{err}");
        let bad = Arc::new(PointSet::new(vec![0.0, 0.0, 1.0, 1.0], 2));
        let err = coord
            .run_sync(ClusterJob::new(bad, DpcParams { d_cut: -1.0, rho_min: 0.0, delta_min: 20.0, ..DpcParams::default() }))
            .unwrap_err();
        assert!(err.contains("d_cut"), "{err}");
    }

    #[test]
    fn session_recut_matches_full_run_and_skips_steps12() {
        let coord = Coordinator::start(tree_only_config()).unwrap();
        let pts = blob_points();
        let sid = coord.open_session(OpenSpec::points(Arc::clone(&pts), 3.0)).unwrap();
        for (rho_min, delta_min) in [(0.0, 20.0), (2.0, 10.0), (0.0, f64::INFINITY)] {
            let out = coord
                .wait(coord.submit_recut(sid, rho_min, delta_min).unwrap())
                .unwrap();
            let fresh = Dpc::new(DpcParams { d_cut: 3.0, rho_min, delta_min, ..DpcParams::default() }).run(&pts).unwrap();
            assert_eq!(out.result.labels, fresh.labels);
            assert_eq!(out.result.rho, fresh.rho);
            assert_eq!(out.result.dep, fresh.dep);
            assert_eq!(out.result.num_clusters, fresh.num_clusters);
            assert_eq!(out.result.num_noise, fresh.num_noise);
        }
        assert_eq!(coord.metrics.counter("sessions_opened"), 1);
        assert_eq!(coord.metrics.counter("recuts_submitted"), 3);
        coord.close_session(sid).unwrap();
    }

    #[test]
    fn density_model_jobs_and_sessions_match_direct_pipeline() {
        let coord = Coordinator::start(tree_only_config()).unwrap();
        let pts = blob_points();
        for model in DensityModel::REPRESENTATIVE {
            let params =
                DpcParams { d_cut: 3.0, rho_min: 0.0, delta_min: 20.0, density: model, ..DpcParams::default() };
            let out = coord.run_sync(ClusterJob::new(Arc::clone(&pts), params)).unwrap();
            let fresh = Dpc::new(params).run(&pts).unwrap();
            assert_eq!(out.result.rho, fresh.rho, "{model}: job rho");
            assert_eq!(out.result.labels, fresh.labels, "{model}: job labels");
            // Session re-cuts inherit the model.
            let sid = coord.open_session(OpenSpec::points(Arc::clone(&pts), 3.0).density(model)).unwrap();
            let recut = coord.wait(coord.submit_recut(sid, 0.0, 20.0).unwrap()).unwrap();
            assert_eq!(recut.result.rho, fresh.rho, "{model}: recut rho");
            assert_eq!(recut.result.dep, fresh.dep, "{model}: recut dep");
            assert_eq!(recut.result.labels, fresh.labels, "{model}: recut labels");
            coord.close_session(sid).unwrap();
        }
    }

    #[test]
    fn density_model_streams_match_fresh_runs() {
        let coord = Coordinator::start(tree_only_config()).unwrap();
        let pts = blob_points();
        let d = pts.dim();
        for model in [DensityModel::KnnRadius { k: 3 }, DensityModel::GaussianKernel] {
            let sid = coord.open_stream(OpenSpec::dim(d, 3.0).density(model)).unwrap();
            for (lo, hi) in [(0usize, 70usize), (70, 160)] {
                let batch = Arc::new(PointSet::new(pts.coords()[lo * d..hi * d].to_vec(), d));
                let out = coord.wait(coord.submit_ingest(sid, batch, 0.0, 20.0).unwrap()).unwrap();
                let prefix = PointSet::new(pts.coords()[..hi * d].to_vec(), d);
                let params = DpcParams {
                    d_cut: 3.0,
                    rho_min: 0.0,
                    delta_min: 20.0,
                    density: model,
                    ..DpcParams::default()
                };
                let fresh = Dpc::new(params).run(&prefix).unwrap();
                assert_eq!(out.result.rho, fresh.rho, "{model}: rho after {hi}");
                assert_eq!(out.result.dep, fresh.dep, "{model}: dep after {hi}");
                assert_eq!(out.result.labels, fresh.labels, "{model}: labels after {hi}");
            }
            coord.close_stream(sid).unwrap();
        }
    }

    #[test]
    fn recut_of_unknown_or_closed_session_is_typed_error() {
        let coord = Coordinator::start(tree_only_config()).unwrap();
        assert!(matches!(coord.submit_recut(42, 0.0, 1.0), Err(DpcError::UnknownSession(42))));
        let sid = coord.open_session(OpenSpec::points(blob_points(), 3.0)).unwrap();
        coord.close_session(sid).unwrap();
        assert!(matches!(coord.close_session(sid), Err(DpcError::UnknownSession(_))));
        assert!(matches!(coord.submit_recut(sid, 0.0, 1.0), Err(DpcError::UnknownSession(_))));
    }

    #[test]
    fn recut_timings_report_cached_stage_costs() {
        let coord = Coordinator::start(tree_only_config()).unwrap();
        let sid = coord.open_session(OpenSpec::points(blob_points(), 3.0)).unwrap();
        let entry = coord.session(sid).unwrap();
        let out = coord.wait(coord.submit_recut(sid, 0.0, 20.0).unwrap()).unwrap();
        // Not just linkage: the density/dep slots carry the cached stages'
        // build costs (Table-3-style reporting stays truthful on recuts).
        assert_eq!(out.result.timings.density_s, entry.density_s);
        assert_eq!(out.result.timings.dep_s, entry.dep_s);
        assert_eq!(entry.build_s(), entry.density_s + entry.dep_s);
    }

    #[test]
    fn stream_ingests_match_fresh_runs_after_every_batch() {
        let coord = Coordinator::start(tree_only_config()).unwrap();
        let pts = blob_points();
        let d = pts.dim();
        let (d_cut, rho_min, delta_min) = (3.0, 0.0, 20.0);
        let sid = coord.open_stream(OpenSpec::dim(d, d_cut)).unwrap();
        for (lo, hi) in [(0usize, 50usize), (50, 61), (61, 160)] {
            let batch = Arc::new(PointSet::new(pts.coords()[lo * d..hi * d].to_vec(), d));
            let out = coord
                .wait(coord.submit_ingest(sid, batch, rho_min, delta_min).unwrap())
                .unwrap();
            let prefix = PointSet::new(pts.coords()[..hi * d].to_vec(), d);
            let fresh = Dpc::new(DpcParams { d_cut, rho_min, delta_min, ..DpcParams::default() }).run(&prefix).unwrap();
            assert_eq!(out.result.rho, fresh.rho, "rho after {hi}");
            assert_eq!(out.result.dep, fresh.dep, "dep after {hi}");
            assert_eq!(out.result.delta, fresh.delta, "delta after {hi}");
            assert_eq!(out.result.labels, fresh.labels, "labels after {hi}");
            assert_eq!(out.result.centers, fresh.centers, "centers after {hi}");
        }
        assert_eq!(out_len(&coord, sid), 160);
        assert_eq!(coord.metrics.counter("streams_opened"), 1);
        assert_eq!(coord.metrics.counter("ingests_submitted"), 3);
        coord.close_stream(sid).unwrap();
        assert!(matches!(coord.close_stream(sid), Err(DpcError::UnknownSession(_))));
    }

    fn out_len(coord: &Coordinator, sid: SessionId) -> usize {
        coord.stream(sid).unwrap().session.lock().len()
    }

    #[test]
    fn concurrent_ingests_apply_in_submission_order() {
        let mut cfg = tree_only_config();
        cfg.workers = 4;
        let coord = Coordinator::start(cfg).unwrap();
        let pts = blob_points();
        let d = pts.dim();
        let sid = coord.open_stream(OpenSpec::dim(d, 3.0)).unwrap();
        // Burst-submit without waiting: workers race the shared queue, but
        // per-stream tickets force batches to land in submission order —
        // point ids (and thus deps/labels) would differ otherwise.
        let bounds = [(0usize, 40usize), (40, 80), (80, 120), (120, 160)];
        let ids: Vec<JobId> = bounds
            .iter()
            .map(|&(lo, hi)| {
                let batch = Arc::new(PointSet::new(pts.coords()[lo * d..hi * d].to_vec(), d));
                coord.submit_ingest(sid, batch, 0.0, 20.0).unwrap()
            })
            .collect();
        for id in ids {
            coord.wait(id).unwrap();
        }
        let fresh = Dpc::new(DpcParams { d_cut: 3.0, rho_min: 0.0, delta_min: 20.0, ..DpcParams::default() }).run(&pts).unwrap();
        let entry = coord.stream(sid).unwrap();
        let s = entry.session.lock();
        assert_eq!(s.rho(), &fresh.rho[..]);
        assert_eq!(s.dep(), &fresh.dep[..]);
        let cut = s.cut(0.0, 20.0).unwrap();
        assert_eq!(cut.labels, fresh.labels);
        assert_eq!(cut.centers, fresh.centers);
    }

    #[test]
    fn close_stream_mid_burst_never_strands_workers() {
        let mut cfg = tree_only_config();
        cfg.workers = 2;
        let coord = Coordinator::start(cfg).unwrap();
        let pts = blob_points();
        let sid = coord.open_stream(OpenSpec::dim(2, 3.0)).unwrap();
        let ids: Vec<JobId> = (0..4)
            .map(|_| coord.submit_ingest(sid, Arc::clone(&pts), 0.0, 20.0).unwrap())
            .collect();
        coord.close_stream(sid).unwrap();
        // The close may race the dequeues arbitrarily; every job must still
        // reach a terminal state (applied in order, or UnknownSession) —
        // this test hangs if a ticket waiter is ever stranded.
        for id in ids {
            let _ = coord.wait(id);
        }
    }

    #[test]
    fn stream_errors_are_typed() {
        let coord = Coordinator::start(tree_only_config()).unwrap();
        assert!(matches!(coord.open_stream(OpenSpec::dim(0, 1.0)), Err(DpcError::InvalidParam { name: "dim", .. })));
        assert!(matches!(
            coord.open_stream(OpenSpec::dim(2, -1.0)),
            Err(DpcError::InvalidParam { name: "d_cut", .. })
        ));
        assert!(matches!(
            coord.open_stream(OpenSpec::points(blob_points(), 1.0)),
            Err(DpcError::InvalidParam { name: "open_spec", .. })
        ));
        assert!(matches!(
            coord.submit_ingest(99, blob_points(), 0.0, 1.0),
            Err(DpcError::UnknownSession(99))
        ));
        let sid = coord.open_stream(OpenSpec::dim(2, 3.0)).unwrap();
        assert!(matches!(
            coord.submit_ingest(sid, blob_points(), f64::NAN, 1.0),
            Err(DpcError::InvalidParam { name: "rho_min", .. })
        ));
        // A wrong-dimension batch fails the job, not the server.
        let bad = Arc::new(PointSet::new(vec![1.0, 2.0, 3.0], 3));
        let err = coord.wait(coord.submit_ingest(sid, bad, 0.0, 1.0).unwrap()).unwrap_err();
        assert!(err.contains("dimension mismatch"), "{err}");
    }

    #[test]
    fn open_session_validates_input() {
        let coord = Coordinator::start(tree_only_config()).unwrap();
        assert!(matches!(
            coord.open_session(OpenSpec::points(Arc::new(PointSet::empty(2)), 1.0)),
            Err(DpcError::EmptyInput)
        ));
        assert!(matches!(
            coord.open_session(OpenSpec::points(blob_points(), f64::NAN)),
            Err(DpcError::InvalidParam { name: "d_cut", .. })
        ));
        assert!(matches!(
            coord.open_session(OpenSpec::dim(2, 1.0)),
            Err(DpcError::InvalidParam { name: "open_spec", .. })
        ));
    }

    fn durable_config(tag: &str) -> (CoordinatorConfig, std::path::PathBuf) {
        let dir = std::env::temp_dir().join(format!("parcluster-serve-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut cfg = tree_only_config();
        cfg.durable_dir = Some(dir.clone());
        (cfg, dir)
    }

    #[test]
    fn checkpoint_requires_durable_mode() {
        let coord = Coordinator::start(tree_only_config()).unwrap();
        assert!(!coord.is_durable());
        assert!(matches!(coord.checkpoint_now(), Err(DpcError::MissingStage { call: "checkpoint", .. })));
    }

    #[test]
    fn durable_restart_restores_streams_and_sessions() {
        let (cfg, dir) = durable_config("restart");
        let pts = blob_points();
        let d = pts.dim();
        let (sid_stream, sid_session);
        {
            let coord = Coordinator::start(cfg.clone()).unwrap();
            assert!(coord.is_durable());
            sid_stream = coord.open_stream(OpenSpec::dim(d, 3.0)).unwrap();
            for (lo, hi) in [(0usize, 60usize), (60, 100)] {
                let batch = Arc::new(PointSet::new(pts.coords()[lo * d..hi * d].to_vec(), d));
                coord.wait(coord.submit_ingest(sid_stream, batch, 0.0, 20.0).unwrap()).unwrap();
            }
            sid_session = coord.open_session(OpenSpec::points(Arc::clone(&pts), 3.0)).unwrap();
            // Checkpoint mid-history, then keep going: recovery must stack
            // the snapshot with the journal suffix.
            let m = coord.checkpoint_now().unwrap();
            assert_eq!(m.checkpoint_seq, 1);
            let batch = Arc::new(PointSet::new(pts.coords()[100 * d..160 * d].to_vec(), d));
            coord.wait(coord.submit_ingest(sid_stream, batch, 0.0, 20.0).unwrap()).unwrap();
            // Simulated crash: drop without closing anything.
        }
        let coord = Coordinator::start(cfg).unwrap();
        let entry = coord.stream(sid_stream).expect("stream survives restart");
        {
            let s = entry.session.lock();
            let fresh = Dpc::new(DpcParams { d_cut: 3.0, rho_min: 0.0, delta_min: 20.0, ..DpcParams::default() })
                .run(&pts)
                .unwrap();
            assert_eq!(s.rho(), &fresh.rho[..], "recovered rho == fresh build");
            assert_eq!(s.dep(), &fresh.dep[..], "recovered dep == fresh build");
            assert_eq!(s.delta(), &fresh.delta[..], "recovered delta == fresh build");
        }
        let sess = coord.session(sid_session).expect("session survives restart");
        assert_eq!(sess.rho.len(), pts.len());
        // The restored server keeps serving: recut + further ingest work,
        // and new ids never collide with recovered ones.
        let out = coord.wait(coord.submit_recut(sid_session, 0.0, 20.0).unwrap()).unwrap();
        assert_eq!(out.result.num_clusters, 2);
        let new_id = coord.open_stream(OpenSpec::dim(d, 3.0)).unwrap();
        assert!(new_id > sid_stream.max(sid_session), "id allocator resumes past recovered ids");
        assert_eq!(coord.stream(sid_stream).unwrap().tag, "recovered");
        assert_eq!(sess.tag, "recovered");
        coord.close_stream(sid_stream).unwrap();
        coord.close_session(sid_session).unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn durable_close_is_durable_too() {
        let (cfg, dir) = durable_config("close");
        {
            let coord = Coordinator::start(cfg.clone()).unwrap();
            let sid = coord.open_stream(OpenSpec::dim(2, 3.0)).unwrap();
            let batch = Arc::new(PointSet::new(vec![0.0, 0.0, 1.0, 1.0], 2));
            coord.wait(coord.submit_ingest(sid, batch, 0.0, 1.0).unwrap()).unwrap();
            coord.close_stream(sid).unwrap();
        }
        let coord = Coordinator::start(cfg).unwrap();
        assert!(coord.shared.streams.lock().is_empty(), "closed stream stays closed");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn f32_streams_ingest_and_survive_restart() {
        let (cfg, dir) = durable_config("f32stream");
        let pts64 = blob_points();
        let pts32 = PointStore::<f32>::cast_from_f64(&pts64);
        let d = pts32.dim();
        let sid;
        {
            let coord = Coordinator::start(cfg.clone()).unwrap();
            sid = coord.open_stream(OpenSpec::dim(d, 3.0).dtype(crate::geom::Dtype::F32)).unwrap();
            assert_eq!(coord.stream(sid).unwrap().dtype, crate::geom::Dtype::F32);
            // A mismatched (f64) batch is a typed error at submit time and
            // never reaches the journal.
            let err = coord
                .submit_ingest_dyn(sid, DynPoints::F64((*pts64).clone()), 0.0, 20.0)
                .unwrap_err();
            assert!(matches!(err, DpcError::DtypeMismatch { expected: "f32", got: "f64" }));
            for (lo, hi) in [(0usize, 90usize), (90, 160)] {
                let batch =
                    DynPoints::F32(PointStore::<f32>::new(pts32.coords()[lo * d..hi * d].to_vec(), d));
                let out = coord.wait(coord.submit_ingest_dyn(sid, batch, 0.0, 20.0).unwrap()).unwrap();
                assert_eq!(out.result.num_clusters, 2);
            }
            // Simulated crash.
        }
        let coord = Coordinator::start(cfg).unwrap();
        let entry = coord.stream(sid).expect("f32 stream survives restart first-class");
        assert_eq!(entry.dtype, crate::geom::Dtype::F32);
        {
            let s = entry.session.lock();
            assert_eq!(s.len(), 160);
            let fresh = Dpc::new(DpcParams {
                d_cut: 3.0,
                rho_min: 0.0,
                delta_min: 20.0,
                dtype: crate::geom::Dtype::F32,
                ..DpcParams::default()
            })
            .run(&pts32)
            .unwrap();
            assert_eq!(s.rho(), &fresh.rho[..], "recovered f32 rho == fresh f32 build");
            assert_eq!(s.dep(), &fresh.dep[..], "recovered f32 dep == fresh f32 build");
        }
        // And it keeps ingesting after recovery — the old warn-and-drop
        // path would have discarded it.
        let more = DynPoints::F32(PointStore::<f32>::new(vec![0.5, 0.5], 2));
        coord.wait(coord.submit_ingest_dyn(sid, more, 0.0, 20.0).unwrap()).unwrap();
        assert_eq!(entry.session.lock().len(), 161);
        coord.close_stream(sid).unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn checkpoints_bound_journal_disk_use() {
        // The bounded-growth contract: ingest → checkpoint loops leave at
        // most ~2× the rotation threshold of journal bytes on disk (the
        // live tail past the replay horizon), no matter how many batches
        // have ever been journaled.
        let (mut cfg, dir) = durable_config("bounded");
        cfg.journal_rotate_bytes = 4096;
        let coord = Coordinator::start(cfg).unwrap();
        let sid = coord.open_stream(OpenSpec::dim(2, 3.0)).unwrap();
        let mut rng = SplitMix64::new(5);
        let mut total_journaled = 0u64;
        for round in 0u64..6 {
            for _ in 0..4 {
                let coords: Vec<f64> = (0..160).map(|_| rng.normal() * 10.0).collect();
                total_journaled += (coords.len() * 8) as u64;
                let batch = Arc::new(PointSet::new(coords, 2));
                coord.wait(coord.submit_ingest(sid, batch, 0.0, 20.0).unwrap()).unwrap();
            }
            let m = coord.checkpoint_now().unwrap();
            assert_eq!(m.checkpoint_seq, round + 1);
            let journal_bytes: u64 = crate::durability::journal::list_segments(&dir)
                .unwrap()
                .iter()
                .map(|(_, p)| std::fs::metadata(p).unwrap().len())
                .sum();
            assert!(
                journal_bytes < 2 * 4096,
                "round {round}: {journal_bytes} journal bytes on disk (threshold 4096)"
            );
        }
        assert!(total_journaled > 4 * 4096, "the test must journal well past the ceiling");
        coord.close_stream(sid).unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn backpressure_rejects_at_the_limit_and_clears_after_drain() {
        let mut cfg = tree_only_config();
        cfg.max_inflight_jobs = 2;
        let coord = Coordinator::start(cfg).unwrap();
        // Deterministic: park two phantom slots so the gate is exactly full
        // (workers can't dequeue jobs that were never enqueued).
        coord.shared.inflight.fetch_add(2, Ordering::AcqRel);
        let job = || ClusterJob::new(blob_points(), DpcParams { d_cut: 3.0, rho_min: 0.0, delta_min: 20.0, ..DpcParams::default() });
        assert!(matches!(
            coord.try_submit(job()),
            Err(DpcError::Backpressure { in_flight: 2, limit: 2 })
        ));
        let sid = coord.open_session(OpenSpec::points(blob_points(), 3.0)).unwrap();
        assert!(matches!(coord.submit_recut(sid, 0.0, 20.0), Err(DpcError::Backpressure { .. })));
        let stream = coord.open_stream(OpenSpec::dim(2, 3.0)).unwrap();
        assert!(matches!(
            coord.submit_ingest(stream, blob_points(), 0.0, 20.0),
            Err(DpcError::Backpressure { .. })
        ));
        assert_eq!(coord.metrics.counter("jobs_rejected_backpressure"), 3);
        // Release the phantom slots: admission recovers immediately.
        coord.shared.inflight.fetch_sub(2, Ordering::AcqRel);
        let id = coord.try_submit(job()).unwrap();
        coord.wait(id).unwrap();
        // The slot release lands just after the terminal status becomes
        // visible; give the worker a beat before asserting it drained.
        for _ in 0..1000 {
            if coord.inflight_jobs() == 0 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(coord.inflight_jobs(), 0, "terminal jobs release their slots");
        // The raw submit entry point stays ungated even at the limit.
        coord.shared.inflight.fetch_add(2, Ordering::AcqRel);
        let id = coord.submit(job());
        coord.wait(id).unwrap();
        coord.shared.inflight.fetch_sub(2, Ordering::AcqRel);
    }

    #[test]
    fn zero_limit_means_unbounded_admission() {
        let coord = Coordinator::start(tree_only_config()).unwrap();
        assert_eq!(coord.cfg.max_inflight_jobs, 0);
        for _ in 0..8 {
            let id = coord
                .try_submit(ClusterJob::new(blob_points(), DpcParams { d_cut: 3.0, rho_min: 0.0, delta_min: 20.0, ..DpcParams::default() }))
                .unwrap();
            coord.wait(id).unwrap();
        }
        for _ in 0..1000 {
            if coord.inflight_jobs() == 0 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(coord.inflight_jobs(), 0);
    }

    #[test]
    fn open_spec_tag_is_echoed_in_job_outputs() {
        let coord = Coordinator::start(tree_only_config()).unwrap();
        let sid = coord
            .open_session(OpenSpec::points(blob_points(), 3.0).tag("tenant-a/run7"))
            .unwrap();
        let out = coord.wait(coord.submit_recut(sid, 0.0, 20.0).unwrap()).unwrap();
        assert_eq!(out.tag, "tenant-a/run7");
        let stream = coord.open_stream(OpenSpec::dim(2, 3.0).tag("tenant-b")).unwrap();
        let batch = Arc::new(PointSet::new(vec![0.0, 0.0, 1.0, 1.0], 2));
        let out = coord.wait(coord.submit_ingest(stream, batch, 0.0, 1.0).unwrap()).unwrap();
        assert_eq!(out.tag, "tenant-b");
        // Untagged opens keep the legacy kind:id tags.
        let sid2 = coord.open_session(OpenSpec::points(blob_points(), 3.0)).unwrap();
        let out = coord.wait(coord.submit_recut(sid2, 0.0, 20.0).unwrap()).unwrap();
        assert_eq!(out.tag, format!("recut:{sid2}"));
    }

    #[test]
    fn id_listings_track_opens_and_closes() {
        let coord = Coordinator::start(tree_only_config()).unwrap();
        assert!(coord.session_ids().is_empty() && coord.stream_ids().is_empty());
        let sid = coord.open_session(OpenSpec::points(blob_points(), 3.0)).unwrap();
        let stream = coord.open_stream(OpenSpec::dim(2, 3.0)).unwrap();
        assert_eq!(coord.session_ids(), vec![sid]);
        assert_eq!(coord.stream_ids(), vec![stream]);
        coord.close_session(sid).unwrap();
        coord.close_stream(stream).unwrap();
        assert!(coord.session_ids().is_empty() && coord.stream_ids().is_empty());
    }

    #[test]
    fn open_spec_density_reaches_session_and_stream_entries() {
        // Replaces the deprecated `open_*_with_model` shim test: the
        // OpenSpec builder is now the only spelling, and the chosen density
        // model must land in the cached entries exactly as the shims did.
        let coord = Coordinator::start(tree_only_config()).unwrap();
        let sid = coord
            .open_session(OpenSpec::points(blob_points(), 3.0).density(DensityModel::GaussianKernel))
            .unwrap();
        assert_eq!(coord.session(sid).unwrap().density, DensityModel::GaussianKernel);
        coord.close_session(sid).unwrap();
        let stream = coord
            .open_stream(OpenSpec::dim(2, 3.0).density(DensityModel::KnnRadius { k: 3 }))
            .unwrap();
        assert_eq!(coord.stream(stream).unwrap().density, DensityModel::KnnRadius { k: 3 });
        coord.close_stream(stream).unwrap();
    }
}
