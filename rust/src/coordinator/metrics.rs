//! Lightweight metrics registry: named atomic counters and duration sums,
//! rendered as a flat text report (`/metrics`-style).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::sync::{rank, OrderedMutex};

/// Map locks rank [`rank::METRICS`] — metrics are bumped while holding
/// nearly any coordinator lock, so they sit just below the pool leaves.
#[derive(Debug, Default)]
pub struct Metrics {
    counters: OrderedMutex<BTreeMap<String, AtomicU64>, { rank::METRICS }>,
    /// Sums stored as f64 bits.
    sums: OrderedMutex<BTreeMap<String, AtomicU64>, { rank::METRICS }>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&self, name: &str) {
        self.add(name, 1);
    }

    pub fn add(&self, name: &str, v: u64) {
        let mut g = self.counters.lock();
        // relaxed: the map lock serializes slot creation; the counter value
        // itself is a monotonic statistic with no ordering dependency.
        g.entry(name.to_string()).or_insert_with(|| AtomicU64::new(0)).fetch_add(v, Ordering::Relaxed);
    }

    pub fn observe_secs(&self, name: &str, secs: f64) {
        let mut g = self.sums.lock();
        let slot = g.entry(name.to_string()).or_insert_with(|| AtomicU64::new(0f64.to_bits()));
        // CAS-loop float accumulation.
        // relaxed: the CAS loop only needs atomicity of the one slot; the
        // sum is a statistic read long after, under the same map lock.
        let mut cur = slot.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + secs).to_bits();
            // relaxed: see above — per-slot atomicity only.
            match slot.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => break,
                Err(c) => cur = c,
            }
        }
    }

    pub fn counter(&self, name: &str) -> u64 {
        // relaxed: statistic read; the map lock orders slot existence.
        self.counters.lock().get(name).map(|a| a.load(Ordering::Relaxed)).unwrap_or(0)
    }

    pub fn sum_secs(&self, name: &str) -> f64 {
        // relaxed: statistic read; the map lock orders slot existence.
        self.sums.lock().get(name).map(|a| f64::from_bits(a.load(Ordering::Relaxed))).unwrap_or(0.0)
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        for (k, v) in self.counters.lock().iter() {
            // relaxed: statistic read; the map lock orders slot existence.
            out.push_str(&format!("{k} {}\n", v.load(Ordering::Relaxed)));
        }
        for (k, v) in self.sums.lock().iter() {
            // relaxed: statistic read; the map lock orders slot existence.
            out.push_str(&format!("{k}_seconds {:.6}\n", f64::from_bits(v.load(Ordering::Relaxed))));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_sums() {
        let m = Metrics::new();
        m.inc("jobs");
        m.inc("jobs");
        m.add("points", 500);
        m.observe_secs("cluster", 0.25);
        m.observe_secs("cluster", 0.5);
        assert_eq!(m.counter("jobs"), 2);
        assert_eq!(m.counter("points"), 500);
        assert!((m.sum_secs("cluster") - 0.75).abs() < 1e-12);
        assert_eq!(m.counter("missing"), 0);
        let r = m.render();
        assert!(r.contains("jobs 2"));
        assert!(r.contains("cluster_seconds 0.75"));
    }
}
