//! Clustering jobs: the unit of work submitted to the [`super::Coordinator`].

use std::sync::Arc;

use crate::dpc::{DpcParams, DpcResult, DepAlgo};
use crate::geom::{DynPoints, PointSet, PointStore};

use super::router::Backend;
use super::service::SessionId;

/// What a job executes against. Point payloads are
/// [`crate::geom::DynPoints`] — the same runtime-tagged, refcount-shared
/// store every other dtype boundary traffics in. (The old `PointsPayload`
/// wrapper added an `Arc` layer solely so the XLA memo could key on its
/// allocation; the memo now keys on the store's own shared coordinate
/// buffer, so the wrapper is gone.)
#[derive(Clone, Debug)]
pub enum JobPayload {
    /// A full three-step pipeline over a point set (either precision).
    /// Cloning shares the store's `Arc<[S]>` buffer — large point sets are
    /// never copied per worker.
    Points(DynPoints),
    /// A linkage-only re-cut against an open session's cached artifacts
    /// (Steps 1–2 are served from the session).
    Recut(SessionId),
    /// A batch ingest into an open streaming session, followed by a cut at
    /// the job's thresholds (Steps 1–2 are incrementally repaired). The
    /// batch is a [`DynPoints`] so f32 streams ingest at their own
    /// precision; cloning shares the store's buffer. `seq` is the stream's
    /// FIFO ticket: workers apply ingests in ticket order, so batches land
    /// in submission order even when several workers race the shared
    /// queue.
    Ingest { stream: SessionId, batch: DynPoints, seq: u64 },
}

/// A clustering request.
#[derive(Clone, Debug)]
pub struct ClusterJob {
    pub payload: JobPayload,
    pub params: DpcParams,
    /// Routing override (None = coordinator default policy).
    pub backend: Option<Backend>,
    /// Step-2 algorithm override for the tree backend.
    pub dep_algo: Option<DepAlgo>,
    /// Free-form tag echoed in the result (dataset name etc.).
    pub tag: String,
}

impl ClusterJob {
    /// A double-precision pipeline job (the pre-generic signature — the
    /// `Arc` wrapper is unwrapped to a plain store clone, which shares the
    /// coordinate buffer by refcount).
    pub fn new(pts: Arc<PointSet>, params: DpcParams) -> Self {
        Self::new_points(DynPoints::F64((*pts).clone()), params)
    }

    /// A single-precision pipeline job.
    pub fn new_f32(pts: Arc<PointStore<f32>>, params: DpcParams) -> Self {
        Self::new_points(DynPoints::F32((*pts).clone()), params)
    }

    /// A pipeline job over an already-tagged payload (what the CLI's
    /// `--dtype` path builds).
    pub fn new_points(pts: DynPoints, params: DpcParams) -> Self {
        ClusterJob { payload: JobPayload::Points(pts), params, backend: None, dep_algo: None, tag: String::new() }
    }

    /// A re-cut of an open session at new thresholds (`d_cut` is fixed by
    /// the session; the field here is filled in from it for reporting).
    pub fn recut(session: SessionId, params: DpcParams) -> Self {
        ClusterJob { payload: JobPayload::Recut(session), params, backend: None, dep_algo: None, tag: String::new() }
    }

    /// A batch ingest into an open streaming session, reporting the
    /// post-ingest clustering at the given thresholds (`d_cut` is fixed by
    /// the stream; the field here is filled in from it for reporting).
    /// `seq` is the per-stream FIFO ticket issued by the coordinator.
    pub fn ingest(stream: SessionId, batch: DynPoints, seq: u64, params: DpcParams) -> Self {
        ClusterJob {
            payload: JobPayload::Ingest { stream, batch, seq },
            params,
            backend: None,
            dep_algo: None,
            tag: String::new(),
        }
    }

    pub fn backend(mut self, b: Backend) -> Self {
        self.backend = Some(b);
        self
    }

    pub fn dep_algo(mut self, a: DepAlgo) -> Self {
        self.dep_algo = Some(a);
        self
    }

    pub fn tag(mut self, t: impl Into<String>) -> Self {
        self.tag = t.into();
        self
    }
}

/// Completed job output.
#[derive(Clone, Debug)]
pub struct JobOutput {
    pub result: DpcResult,
    /// Which backend actually ran (Auto resolves to a concrete one).
    pub backend_used: Backend,
    pub wall_s: f64,
    pub tag: String,
}

/// Lifecycle of a submitted job.
#[derive(Clone, Debug)]
pub enum JobStatus {
    Queued,
    Running,
    Done(Box<JobOutput>),
    Failed(String),
}

impl JobStatus {
    pub fn is_terminal(&self) -> bool {
        matches!(self, JobStatus::Done(_) | JobStatus::Failed(_))
    }
}
