//! Clustering jobs: the unit of work submitted to the [`super::Coordinator`].

use std::sync::Arc;

use crate::dpc::{DpcParams, DpcResult, DepAlgo};
use crate::geom::PointSet;

use super::router::Backend;

/// A clustering request.
#[derive(Clone)]
pub struct ClusterJob {
    /// Shared so large point sets are not copied per worker.
    pub pts: Arc<PointSet>,
    pub params: DpcParams,
    /// Routing override (None = coordinator default policy).
    pub backend: Option<Backend>,
    /// Step-2 algorithm override for the tree backend.
    pub dep_algo: Option<DepAlgo>,
    /// Free-form tag echoed in the result (dataset name etc.).
    pub tag: String,
}

impl ClusterJob {
    pub fn new(pts: Arc<PointSet>, params: DpcParams) -> Self {
        ClusterJob { pts, params, backend: None, dep_algo: None, tag: String::new() }
    }

    pub fn backend(mut self, b: Backend) -> Self {
        self.backend = Some(b);
        self
    }

    pub fn dep_algo(mut self, a: DepAlgo) -> Self {
        self.dep_algo = Some(a);
        self
    }

    pub fn tag(mut self, t: impl Into<String>) -> Self {
        self.tag = t.into();
        self
    }
}

/// Completed job output.
#[derive(Clone, Debug)]
pub struct JobOutput {
    pub result: DpcResult,
    /// Which backend actually ran (Auto resolves to a concrete one).
    pub backend_used: Backend,
    pub wall_s: f64,
    pub tag: String,
}

/// Lifecycle of a submitted job.
#[derive(Clone, Debug)]
pub enum JobStatus {
    Queued,
    Running,
    Done(Box<JobOutput>),
    Failed(String),
}

impl JobStatus {
    pub fn is_terminal(&self) -> bool {
        matches!(self, JobStatus::Done(_) | JobStatus::Failed(_))
    }
}
