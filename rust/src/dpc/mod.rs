//! Density Peaks Clustering: the paper's three-step pipeline.
//!
//! 1. **Density** (§6.1): by default ρ(x) = #points within `d_cut` of x —
//!    parallel kd-tree range counts with the subtree-count pruning
//!    optimization. The density *definition* is pluggable ([`DensityModel`]):
//!    a kNN-rank density and a fixed-point truncated Gaussian kernel run
//!    through the same integer-ρ pipeline, exactly.
//! 2. **Dependent points** (§4, §5): λ(x) = nearest strictly-higher-priority
//!    neighbor, where priority = (ρ, lexicographic id tiebreak). Five
//!    interchangeable algorithms, all *exact* (see [`DepAlgo`]).
//! 3. **Single-linkage cut** (§6.2): union every non-noise non-center point
//!    with its dependent point via lock-free union-find; components =
//!    clusters, ρ < ρ_min = noise.
//!
//! All five Step-2 algorithms produce byte-identical (λ, δ) arrays (this is
//! an invariant under property test — exactness is the paper's headline
//! claim vs. approximate DPC).

pub mod dep;
pub mod density;
pub mod linkage;
pub mod approx;
pub mod decision;
pub mod oracle;
pub mod session;
pub mod stream;

pub use density::{compute_density_model, epanechnikov_weight, gaussian_weight, pair_weight, DensityModel, GAUSS_SCALE};
pub use session::{ClusterSession, DepArtifacts, SessionStats};
pub use stream::{StreamState, StreamStats, StreamingSession};

use crate::error::DpcError;
use crate::geom::{radius_sq, PointStore, Scalar};
use crate::kdtree::{KdTree, NoStats};
use crate::parlay;

pub use crate::geom::Dtype;

/// DPC hyper-parameters (Table 2 of the paper lists per-dataset choices).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DpcParams {
    /// Density radius (ρ(x) counts points with D(x,·) ≤ d_cut).
    /// Interpreted at the store's precision: every layer converts it with
    /// [`crate::geom::radius_sq`] (round the radius, then square in `S`).
    pub d_cut: f64,
    /// Noise threshold: ρ < ρ_min ⇒ noise point (Definition 4).
    pub rho_min: f64,
    /// Cluster-center threshold: δ ≥ δ_min ⇒ center (Definition 5).
    pub delta_min: f64,
    /// Requested coordinate precision. The generic pipeline entry points
    /// ignore it (the store's scalar type is the source of truth); dtype
    /// boundaries — the CLI, `serve` lines, and the coordinator's ingestion
    /// of raw f64 data — use it to pick which [`PointStore`] to build.
    pub dtype: Dtype,
    /// The density *definition* Step 1 computes (cutoff count by default —
    /// the paper's model; see [`DensityModel`] for the kNN-rank and
    /// fixed-point Gaussian alternatives). ρ_min is interpreted in the
    /// model's own units: a neighbor count, a rank in `0..n`, or a
    /// fixed-point kernel mass (multiples of [`density::GAUSS_SCALE`]).
    pub density: DensityModel,
}

impl Default for DpcParams {
    fn default() -> Self {
        DpcParams {
            d_cut: 1.0,
            rho_min: 0.0,
            delta_min: f64::INFINITY,
            dtype: Dtype::F64,
            density: DensityModel::CutoffCount,
        }
    }
}

/// Dependent-point-finding algorithm (Step 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DepAlgo {
    /// Θ(n²) all-pairs scan (the "Original DPC" of Table 1).
    Naive,
    /// Amagata–Hara's incremental kd-tree with a sequential insert loop
    /// (DPC-EXACT-BASELINE).
    ExactBaseline,
    /// §4.1 incomplete kd-tree, sequential activation loop (DPC-INCOMPLETE).
    Incomplete,
    /// §4.3 priority search kd-tree, fully parallel (DPC-PRIORITY).
    Priority,
    /// §5 Fenwick tree of kd-trees, fully parallel (DPC-FENWICK).
    Fenwick,
}

impl DepAlgo {
    pub const ALL: [DepAlgo; 5] =
        [DepAlgo::Naive, DepAlgo::ExactBaseline, DepAlgo::Incomplete, DepAlgo::Priority, DepAlgo::Fenwick];

    pub fn name(&self) -> &'static str {
        match self {
            DepAlgo::Naive => "naive",
            DepAlgo::ExactBaseline => "exact-baseline",
            DepAlgo::Incomplete => "incomplete",
            DepAlgo::Priority => "priority",
            DepAlgo::Fenwick => "fenwick",
        }
    }
}

/// Density-computation variant (Step 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DensityAlgo {
    /// kd-tree range count **with** §6.1 subtree-count pruning (ours).
    TreePruned,
    /// Arena kd-tree range count without the containment shortcut (ablation:
    /// isolates the §6.1 pruning effect from the allocation/layout effect).
    TreeNoPrune,
    /// DPC-EXACT-BASELINE's density step: pointer-based kd-tree with
    /// individually heap-allocated nodes (built by randomized insertion),
    /// no containment pruning — models Amagata–Hara's implementation,
    /// whose dynamic allocation the paper calls out as a cache liability
    /// (§7.2).
    BaselineIncremental,
    /// Θ(n²) all-pairs (the "Original DPC" of Table 1).
    Naive,
}

impl DensityAlgo {
    pub const ALL: [DensityAlgo; 4] =
        [DensityAlgo::TreePruned, DensityAlgo::TreeNoPrune, DensityAlgo::BaselineIncremental, DensityAlgo::Naive];

    pub fn name(&self) -> &'static str {
        match self {
            DensityAlgo::TreePruned => "tree-pruned",
            DensityAlgo::TreeNoPrune => "tree-noprune",
            DensityAlgo::BaselineIncremental => "baseline-incremental",
            DensityAlgo::Naive => "naive",
        }
    }
}

/// The priority key: density-major, then *smaller id wins* ties
/// (Definition 2's lexicographic tiebreak). Unique per point.
#[inline]
pub fn priority_key(rho: u32, id: u32) -> u64 {
    ((rho as u64) << 32) | (u32::MAX - id) as u64
}

/// Per-step wall-clock timings (seconds) — the rows of Table 3.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepTimings {
    pub density_s: f64,
    pub dep_s: f64,
    pub linkage_s: f64,
}

impl StepTimings {
    pub fn total_s(&self) -> f64 {
        self.density_s + self.dep_s + self.linkage_s
    }
}

/// Full clustering output.
#[derive(Clone, Debug)]
pub struct DpcResult {
    /// ρ(x_i): #points within d_cut (self-inclusive).
    pub rho: Vec<u32>,
    /// λ(x_i): dependent point id; `None` for noise points and the global
    /// density peak.
    pub dep: Vec<Option<u32>>,
    /// δ(x_i): dependent distance; ∞ for the peak, NaN-free.
    pub delta: Vec<f64>,
    /// Cluster label per point (−1 = noise). Labels are center point ids.
    pub labels: Vec<i64>,
    /// Cluster-center point ids.
    pub centers: Vec<u32>,
    pub num_clusters: usize,
    pub num_noise: usize,
    pub timings: StepTimings,
}

/// DPC pipeline runner (builder-style).
#[derive(Clone, Debug)]
pub struct Dpc {
    params: DpcParams,
    dep_algo: DepAlgo,
    density_algo: DensityAlgo,
}

impl Dpc {
    pub fn new(params: DpcParams) -> Self {
        Dpc { params, dep_algo: DepAlgo::Priority, density_algo: DensityAlgo::TreePruned }
    }

    pub fn dep_algo(mut self, a: DepAlgo) -> Self {
        self.dep_algo = a;
        self
    }

    pub fn density_algo(mut self, a: DensityAlgo) -> Self {
        self.density_algo = a;
        self
    }

    pub fn params(&self) -> DpcParams {
        self.params
    }

    /// Run the full three-step pipeline: a thin wrapper over a one-shot
    /// [`ClusterSession`]. Malformed input (empty/non-finite points, bad
    /// parameters) surfaces as [`DpcError`] — iterative workflows should
    /// hold a session directly and re-[`ClusterSession::cut`] instead of
    /// re-running.
    ///
    /// Trade-off: the session computes the full `rho_min = 0` dependency
    /// forest and masks it, so a one-shot run with a large noise fraction
    /// does Step-2 queries the old thresholded pipeline skipped. Callers
    /// that want exactly the thresholded work and no caching can still
    /// compose [`compute_density`] + [`dep::compute_dependents`] +
    /// [`linkage::single_linkage`] directly (the coordinator's per-job
    /// pipeline does).
    ///
    /// Generic over the store's [`Scalar`]: pass a `PointStore<f32>` to run
    /// the identical (exact-per-precision) pipeline at half the memory
    /// bandwidth. `params.dtype` is not consulted here — the store's own
    /// precision is authoritative.
    pub fn run<S: Scalar>(&self, pts: &PointStore<S>) -> Result<DpcResult, DpcError> {
        session::validate_params(&self.params)?;
        let mut s = ClusterSession::build(pts)?.with_density_algo(self.density_algo);
        s.run(self.params, self.dep_algo)
    }
}

/// Grain for loops whose per-index body is a tree traversal: the cost is
/// large and skewed (dense queries visit far more nodes), so chunks finer
/// than [`parlay::auto_grain`]'s default give the work-stealing scheduler
/// something to rebalance.
pub(crate) const QUERY_GRAIN: usize = 64;

/// Step 1: ρ for every point. Generic over the store's [`Scalar`]; the
/// radius is interpreted at that precision (see [`radius_sq`]).
pub fn compute_density<S: Scalar>(pts: &PointStore<S>, d_cut: f64, algo: DensityAlgo) -> Vec<u32> {
    let r_sq: S = radius_sq(d_cut);
    match algo {
        DensityAlgo::Naive => {
            let n = pts.len();
            parlay::par_map_grained(n, QUERY_GRAIN, |i| {
                let q = pts.point(i);
                let mut c = 0u32;
                for j in 0..n {
                    if pts.dist_sq_to(j, q) <= r_sq {
                        c += 1;
                    }
                }
                c
            })
        }
        DensityAlgo::TreePruned | DensityAlgo::TreeNoPrune => {
            let tree = KdTree::build(pts);
            let prune = algo == DensityAlgo::TreePruned;
            parlay::par_map_grained(pts.len(), QUERY_GRAIN, |i| {
                let q = pts.point(i);
                let c = if prune {
                    tree.range_count(q, r_sq, &mut NoStats)
                } else {
                    tree.range_count_noprune(q, r_sq, &mut NoStats)
                };
                c as u32
            })
        }
        DensityAlgo::BaselineIncremental => {
            // Randomized insertion order gives expected O(log n) depth —
            // modeling the baseline's bulk-built but pointer-based tree.
            let mut order: Vec<u32> = (0..pts.len() as u32).collect();
            let mut rng = crate::prng::SplitMix64::new(0xBA5E_11E5);
            rng.shuffle(&mut order);
            let mut tree = crate::kdtree::incremental::IncrementalKdTree::new(pts);
            for &p in &order {
                tree.insert(p);
            }
            parlay::par_map_grained(pts.len(), QUERY_GRAIN, |i| {
                tree.range_count(pts.point(i), r_sq, &mut NoStats) as u32
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::PointSet;
    use crate::proputil::{gen_clustered_points, gen_uniform_points};
    use crate::prng::SplitMix64;

    #[test]
    fn priority_key_orders_by_density_then_smaller_id() {
        // Higher density wins.
        assert!(priority_key(5, 100) > priority_key(4, 0));
        // Equal density: smaller id has higher priority.
        assert!(priority_key(5, 3) > priority_key(5, 4));
        // Unique.
        assert_ne!(priority_key(5, 3), priority_key(5, 4));
    }

    #[test]
    fn density_variants_agree() {
        let mut rng = SplitMix64::new(41);
        let pts = gen_uniform_points(&mut rng, 800, 2, 50.0);
        let a = compute_density(&pts, 5.0, DensityAlgo::Naive);
        for algo in [DensityAlgo::TreePruned, DensityAlgo::TreeNoPrune, DensityAlgo::BaselineIncremental] {
            assert_eq!(a, compute_density(&pts, 5.0, algo), "{algo:?}");
        }
    }

    #[test]
    fn density_is_self_inclusive() {
        let pts = PointSet::new(vec![0.0, 0.0, 10.0, 10.0], 2);
        let rho = compute_density(&pts, 1.0, DensityAlgo::TreePruned);
        assert_eq!(rho, vec![1, 1]);
    }

    #[test]
    fn pipeline_separates_two_blobs() {
        let mut rng = SplitMix64::new(42);
        // Two well-separated tight blobs.
        let mut coords = Vec::new();
        for _ in 0..100 {
            coords.push(rng.uniform(0.0, 5.0));
            coords.push(rng.uniform(0.0, 5.0));
        }
        for _ in 0..100 {
            coords.push(rng.uniform(100.0, 105.0));
            coords.push(rng.uniform(100.0, 105.0));
        }
        let pts = PointSet::new(coords, 2);
        let params = DpcParams { d_cut: 3.0, rho_min: 0.0, delta_min: 20.0, ..DpcParams::default() };
        for algo in DepAlgo::ALL {
            let out = Dpc::new(params).dep_algo(algo).run(&pts).unwrap();
            assert_eq!(out.num_clusters, 2, "algo {algo:?}");
            assert_eq!(out.num_noise, 0);
            // All points in each blob share one label.
            let l0 = out.labels[0];
            assert!(out.labels[..100].iter().all(|&l| l == l0));
            let l1 = out.labels[100];
            assert!(out.labels[100..].iter().all(|&l| l == l1));
            assert_ne!(l0, l1);
        }
    }

    #[test]
    fn all_dep_algos_identical_results() {
        let mut rng = SplitMix64::new(43);
        let pts = gen_clustered_points(&mut rng, 500, 2, 4, 100.0, 3.0);
        let params = DpcParams { d_cut: 5.0, rho_min: 2.0, delta_min: 10.0, ..DpcParams::default() };
        let reference = Dpc::new(params).dep_algo(DepAlgo::Naive).run(&pts).unwrap();
        for algo in [DepAlgo::ExactBaseline, DepAlgo::Incomplete, DepAlgo::Priority, DepAlgo::Fenwick] {
            let out = Dpc::new(params).dep_algo(algo).run(&pts).unwrap();
            assert_eq!(out.rho, reference.rho, "{algo:?} rho");
            assert_eq!(out.dep, reference.dep, "{algo:?} dep");
            assert_eq!(out.labels, reference.labels, "{algo:?} labels");
        }
    }

    #[test]
    fn noise_points_are_labeled_minus_one() {
        let mut rng = SplitMix64::new(44);
        // Dense blob + isolated far-away stragglers.
        let mut coords = Vec::new();
        for _ in 0..200 {
            coords.push(rng.uniform(0.0, 5.0));
            coords.push(rng.uniform(0.0, 5.0));
        }
        for i in 0..5 {
            coords.push(1000.0 + 50.0 * i as f64);
            coords.push(1000.0);
        }
        let pts = PointSet::new(coords, 2);
        let params = DpcParams { d_cut: 3.0, rho_min: 5.0, delta_min: 100.0, ..DpcParams::default() };
        let out = Dpc::new(params).run(&pts).unwrap();
        assert_eq!(out.num_noise, 5);
        for i in 200..205 {
            assert_eq!(out.labels[i], -1);
            assert_eq!(out.dep[i], None);
        }
        assert!(out.num_clusters >= 1);
    }
}
