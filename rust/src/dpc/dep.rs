//! Step 2 — dependent point finding, five exact algorithms.
//!
//! All variants compute, for every non-noise point `x_i`, the nearest
//! neighbor among points with strictly higher priority (density with the
//! lexicographic id tiebreak, [`super::priority_key`]); distance ties are
//! broken by smaller id. Noise points (ρ < ρ_min) are skipped (their λ is
//! `None`), matching Algorithm 1 line 3 / Algorithm 2 line 14.
//!
//! Note a subtlety the paper relies on: the dependent point of a *non-noise*
//! point is always itself non-noise (it has strictly higher density), so
//! skipping noise queries never breaks the dependency forest of Step 3.

use crate::dpc::priority_key;
use crate::fenwick::FenwickDep;
use crate::geom::{PointStore, Scalar};
use crate::kdtree::incomplete::IncompleteKdTree;
use crate::kdtree::incremental::IncrementalKdTree;
use crate::kdtree::{KdTree, NoStats};
use crate::parlay;
use crate::pskd::PriorityKdTree;

use super::DepAlgo;

/// Dispatch to the chosen algorithm. Returns `dep[i] = Some(λ(x_i))`, or
/// `None` for noise points and the global priority peak.
pub fn compute_dependents<S: Scalar>(pts: &PointStore<S>, rho: &[u32], rho_min: f64, algo: DepAlgo) -> Vec<Option<u32>> {
    match algo {
        DepAlgo::Naive => dep_naive(pts, rho, rho_min),
        DepAlgo::ExactBaseline => dep_exact_baseline(pts, rho, rho_min),
        DepAlgo::Incomplete => dep_incomplete(pts, rho, rho_min),
        DepAlgo::Priority => dep_priority(pts, rho, rho_min),
        DepAlgo::Fenwick => dep_fenwick(pts, rho, rho_min),
    }
}

/// δ(x_i) = D(x_i, λ(x_i)); ∞ where λ is undefined (Definition 3). The
/// squared distance accumulates in `S`; the single sqrt always runs in f64,
/// so δ is bit-deterministic per precision (and across precisions whenever
/// the coordinates are losslessly representable in both).
pub fn dependent_distances<S: Scalar>(pts: &PointStore<S>, dep: &[Option<u32>]) -> Vec<f64> {
    parlay::par_map(dep.len(), |i| match dep[i] {
        Some(j) => pts.dist_sq(i, j as usize).to_f64().sqrt(),
        None => f64::INFINITY,
    })
}

fn gammas(rho: &[u32]) -> Vec<u64> {
    rho.iter().enumerate().map(|(i, &r)| priority_key(r, i as u32)).collect()
}

/// Θ(n²) all-pairs scan ("Original DPC" row of Table 1): parallel over
/// queries, O(1) span each.
pub fn dep_naive<S: Scalar>(pts: &PointStore<S>, rho: &[u32], rho_min: f64) -> Vec<Option<u32>> {
    let n = pts.len();
    let gamma = gammas(rho);
    parlay::par_map_grained(n, crate::dpc::QUERY_GRAIN, |i| {
        if (rho[i] as f64) < rho_min {
            return None;
        }
        let gi = gamma[i];
        let q = pts.point(i);
        let mut best: Option<(u32, S)> = None;
        for j in 0..n {
            if gamma[j] <= gi {
                continue;
            }
            let ds = pts.dist_sq_to(j, q);
            match best {
                Some((bj, bd)) if ds > bd || (ds == bd && j as u32 > bj) => {}
                _ => best = Some((j as u32, ds)),
            }
        }
        best.map(|(j, _)| j)
    })
}

/// Ids sorted by descending priority.
fn desc_priority_order(gamma: &[u64]) -> Vec<u32> {
    let mut items: Vec<(u64, u32)> = gamma.iter().enumerate().map(|(i, &g)| (!g, i as u32)).collect();
    parlay::par_radix_sort_u64(&mut items);
    items.into_iter().map(|(_, id)| id).collect()
}

/// DPC-EXACT-BASELINE (Amagata–Hara [3]): points inserted into an
/// *incremental* kd-tree in descending priority order; each point queries its
/// NN among previously-inserted (= higher priority) points, **sequentially**.
pub fn dep_exact_baseline<S: Scalar>(pts: &PointStore<S>, rho: &[u32], rho_min: f64) -> Vec<Option<u32>> {
    let gamma = gammas(rho);
    let order = desc_priority_order(&gamma);
    let mut tree = IncrementalKdTree::new(pts);
    let mut dep = vec![None; pts.len()];
    for &p in &order {
        if (rho[p as usize] as f64) >= rho_min && !tree.is_empty() {
            dep[p as usize] = tree.nn(pts.point(p as usize), p, &mut NoStats).map(|(j, _)| j);
        }
        tree.insert(p);
    }
    dep
}

/// DPC-INCOMPLETE (§4.1): same sequential loop, but over a balanced
/// *incomplete* kd-tree — activation replaces insertion, queries prune
/// inactive subtrees. Faster per query; still O(n log n) span overall.
pub fn dep_incomplete<S: Scalar>(pts: &PointStore<S>, rho: &[u32], rho_min: f64) -> Vec<Option<u32>> {
    let gamma = gammas(rho);
    let order = desc_priority_order(&gamma);
    let tree = KdTree::build_with_maps(pts);
    let inc = IncompleteKdTree::new(&tree);
    let mut dep = vec![None; pts.len()];
    let mut first = true;
    for &p in &order {
        if !first && (rho[p as usize] as f64) >= rho_min {
            dep[p as usize] = inc.nn(pts.point(p as usize), p, &mut NoStats).map(|(j, _)| j);
        }
        inc.activate(p);
        first = false;
    }
    dep
}

/// DPC-PRIORITY (§4.3, Algorithm 1): build a priority search kd-tree once,
/// then one fully-parallel priority-NN query per non-noise point.
pub fn dep_priority<S: Scalar>(pts: &PointStore<S>, rho: &[u32], rho_min: f64) -> Vec<Option<u32>> {
    let gamma = gammas(rho);
    let tree = PriorityKdTree::build(pts, &gamma);
    parlay::par_map_grained(pts.len(), crate::dpc::QUERY_GRAIN, |i| {
        if (rho[i] as f64) < rho_min {
            return None;
        }
        tree.priority_nn(pts.point(i), gamma[i], &mut NoStats).map(|(j, _)| j)
    })
}

/// DPC-FENWICK (§5, Algorithm 2): Fenwick decomposition over the descending
/// density order, one kd-tree per block, fully-parallel queries.
pub fn dep_fenwick<S: Scalar>(pts: &PointStore<S>, rho: &[u32], rho_min: f64) -> Vec<Option<u32>> {
    let gamma = gammas(rho);
    let fen = FenwickDep::build(pts, &gamma);
    parlay::par_map_grained(pts.len(), crate::dpc::QUERY_GRAIN, |i| {
        if (rho[i] as f64) < rho_min {
            return None;
        }
        fen.query(i as u32, &mut NoStats).map(|(j, _)| j)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpc::{compute_density, DensityAlgo};
    use crate::geom::PointSet;
    use crate::proputil::{gen_clustered_points, gen_degenerate_points, gen_uniform_points};
    use crate::prng::SplitMix64;

    fn check_all_agree(pts: &PointSet, d_cut: f64, rho_min: f64) {
        let rho = compute_density(pts, d_cut, DensityAlgo::TreePruned);
        let reference = dep_naive(pts, &rho, rho_min);
        for algo in [DepAlgo::ExactBaseline, DepAlgo::Incomplete, DepAlgo::Priority, DepAlgo::Fenwick] {
            let got = compute_dependents(pts, &rho, rho_min, algo);
            assert_eq!(got, reference, "{algo:?} disagrees with naive");
        }
    }

    #[test]
    fn all_algos_agree_uniform() {
        let mut rng = SplitMix64::new(51);
        let pts = gen_uniform_points(&mut rng, 600, 2, 50.0);
        check_all_agree(&pts, 4.0, 0.0);
    }

    #[test]
    fn all_algos_agree_clustered_3d() {
        let mut rng = SplitMix64::new(52);
        let pts = gen_clustered_points(&mut rng, 500, 3, 5, 60.0, 2.0);
        check_all_agree(&pts, 3.0, 0.0);
    }

    #[test]
    fn all_algos_agree_with_noise_threshold() {
        let mut rng = SplitMix64::new(53);
        let pts = gen_uniform_points(&mut rng, 400, 2, 80.0);
        check_all_agree(&pts, 5.0, 3.0);
    }

    #[test]
    fn all_algos_agree_degenerate_ties() {
        let mut rng = SplitMix64::new(54);
        // Heavy duplicates => massive density ties => stresses the
        // lexicographic tiebreak path in every algorithm.
        let pts = gen_degenerate_points(&mut rng, 150, 2);
        check_all_agree(&pts, 2.0, 0.0);
    }

    #[test]
    fn exactly_one_peak_has_no_dependent() {
        let mut rng = SplitMix64::new(55);
        let pts = gen_uniform_points(&mut rng, 300, 2, 30.0);
        let rho = compute_density(&pts, 4.0, DensityAlgo::TreePruned);
        let dep = dep_priority(&pts, &rho, 0.0);
        let peaks = dep.iter().filter(|d| d.is_none()).count();
        assert_eq!(peaks, 1);
    }

    #[test]
    fn dependent_has_strictly_higher_priority() {
        let mut rng = SplitMix64::new(56);
        let pts = gen_clustered_points(&mut rng, 400, 2, 3, 40.0, 2.0);
        let rho = compute_density(&pts, 3.0, DensityAlgo::TreePruned);
        let dep = dep_fenwick(&pts, &rho, 0.0);
        for (i, d) in dep.iter().enumerate() {
            if let Some(j) = d {
                assert!(
                    priority_key(rho[*j as usize], *j) > priority_key(rho[i], i as u32),
                    "dep of {i} must have higher priority"
                );
            }
        }
    }

    #[test]
    fn dependent_distances_match_deps() {
        let pts = PointSet::new(vec![0.0, 0.0, 1.0, 0.0, 0.0, 2.0], 2);
        let dep = vec![Some(1), None, Some(0)];
        let delta = dependent_distances(&pts, &dep);
        assert_eq!(delta[0], 1.0);
        assert!(delta[1].is_infinite());
        assert_eq!(delta[2], 2.0);
    }
}
