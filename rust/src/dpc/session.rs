//! Staged clustering sessions: amortize index construction across repeated
//! parameter queries.
//!
//! The Rodriguez–Laio workflow (§6.2) is iterative — cluster once, inspect
//! the ρ–δ decision graph, re-cut with new `rho_min`/`delta_min` — yet only
//! Step 3 (single-linkage) depends on those thresholds. A
//! [`ClusterSession`] therefore splits the pipeline into cached stages:
//!
//! 1. [`ClusterSession::build`] validates the input and pins the caller's
//!    [`PointStore`] **by refcount** (the `Arc<[S]>` coordinate buffer is
//!    shared, never copied — [`SessionStats::tree_shares_store`] is the
//!    live observable); the session's kd-tree is built **once** on the first
//!    tree-backed density call and shares the same buffer;
//! 2. [`ClusterSession::density`] computes ρ for a radius, cached per
//!    `d_cut`;
//! 3. [`ClusterSession::dependents`] computes the *full* dependency forest
//!    (λ, δ) on top of the cached density, cached per (`d_cut`, algorithm);
//! 4. [`ClusterSession::cut`] runs only the union-find linkage against the
//!    cached artifacts — a decision-graph re-cut costs Step 3 alone.
//!
//! A cut is byte-identical to a fresh full run at the same parameters: the
//! candidate set of a dependent-point query is never filtered by `rho_min`
//! (only *queries* are skipped for noise points), so masking the full forest
//! by a threshold reproduces exactly what a thresholded Step 2 would have
//! produced. `rust/tests/session.rs` holds the property proof.
//!
//! Sessions are generic over the coordinate [`Scalar`]; an f32 session runs
//! the identical algorithms on half the memory bandwidth, exact at f32
//! precision (and byte-identical to f64 on losslessly-representable data).

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use crate::error::DpcError;
use crate::geom::{radius_sq, PointStore, Scalar};
use crate::kdtree::{KdTree, NoStats};
use crate::parlay;

use super::{compute_density, density, dep, linkage, DensityAlgo, DensityModel, DepAlgo, DpcParams, DpcResult, StepTimings};

/// Cached Step-2 output: the full (unthresholded) dependency forest.
#[derive(Clone, Debug)]
pub struct DepArtifacts {
    /// λ(x_i) computed with `rho_min = 0` — `None` only for the global peak.
    pub dep: Vec<Option<u32>>,
    /// δ(x_i) = D(x_i, λ(x_i)); ∞ for the peak.
    pub delta: Vec<f64>,
    /// Wall-clock seconds spent computing this artifact.
    pub secs: f64,
}

/// Cached Step-1 output for one radius.
#[derive(Clone, Debug)]
struct DensityArtifacts {
    rho: Arc<Vec<u32>>,
    secs: f64,
}

/// Compute/reuse counters — the observable proof that re-cuts do not redo
/// Steps 1–2, and that the session never deep-copies its input.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SessionStats {
    pub density_computes: u64,
    pub density_cache_hits: u64,
    pub dep_computes: u64,
    pub dep_cache_hits: u64,
    /// Does the session's kd-tree alias the session store's coordinate
    /// buffer? Computed **live** in [`ClusterSession::stats`] by pointer
    /// comparison (vacuously `true` before the tree exists) — a regression
    /// that rebuilds the tree over a deep copy shows up here as `false`,
    /// with no counter anyone has to remember to bump. Caller-side
    /// aliasing is checked via [`ClusterSession::shares_storage_with`].
    pub tree_shares_store: bool,
}

/// A staged, artifact-caching clustering session over one point set.
///
/// ```no_run
/// use parcluster::dpc::{ClusterSession, DepAlgo};
/// use parcluster::datasets::synthetic;
///
/// let pts = synthetic::uniform(10_000, 2, 1000.0, 42);
/// let mut s = ClusterSession::build(&pts)?;
/// s.density(30.0)?;
/// s.dependents(DepAlgo::Priority)?;
/// let first = s.cut(0.0, 100.0)?; // full pipeline price, artifacts cached
/// let recut = s.cut(5.0, 200.0)?; // linkage-only price
/// assert_eq!(first.rho, recut.rho);
/// # Ok::<(), parcluster::error::DpcError>(())
/// ```
pub struct ClusterSession<S: Scalar = f64> {
    /// Refcount share of the caller's store (no coordinate copy).
    pts: PointStore<S>,
    /// The session's amortized index: built on the first tree-backed
    /// density call, then reused by every later radius. Lazy so the
    /// baseline/naive density ablations never pay for a tree they don't
    /// traverse. Shares the store's buffer by refcount.
    tree: Option<KdTree<S>>,
    density_algo: DensityAlgo,
    /// The density definition `density()` computes (cache keys carry it, so
    /// switching models — like switching radii — re-stages cheaply).
    density_model: DensityModel,
    rho_cache: HashMap<(u64, DensityModel), DensityArtifacts>,
    dep_cache: HashMap<(u64, DensityModel, DepAlgo), Arc<DepArtifacts>>,
    /// (radius, model) of the most recent `density` call (the radius keys
    /// by its f64 bits).
    active_stage: Option<(f64, DensityModel)>,
    /// Algorithm of the most recent `dependents` call for the active stage.
    active_algo: Option<DepAlgo>,
    stats: SessionStats,
}

impl<S: Scalar> std::fmt::Debug for ClusterSession<S> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ClusterSession")
            .field("len", &self.pts.len())
            .field("density_algo", &self.density_algo)
            .field("active_stage", &self.active_stage)
            .finish_non_exhaustive()
    }
}

impl<S: Scalar> ClusterSession<S> {
    /// Validate the input (non-empty, finite coordinates) and open the
    /// session over a refcount share of `pts`. The owned kd-tree is built
    /// on the first tree-backed `density` call and amortized across every
    /// radius after that.
    pub fn build(pts: &PointStore<S>) -> Result<Self, DpcError> {
        if pts.is_empty() {
            return Err(DpcError::EmptyInput);
        }
        pts.validate_finite()?;
        Ok(ClusterSession {
            pts: pts.clone(),
            tree: None,
            density_algo: DensityAlgo::TreePruned,
            density_model: DensityModel::CutoffCount,
            rho_cache: HashMap::new(),
            dep_cache: HashMap::new(),
            active_stage: None,
            active_algo: None,
            stats: SessionStats::default(),
        })
    }

    /// Select the Step-1 variant. The session's owned tree serves
    /// `TreePruned`/`TreeNoPrune`; the baseline variants rebuild their own
    /// structures per radius (they exist for ablations, not serving).
    pub fn with_density_algo(mut self, a: DensityAlgo) -> Self {
        self.density_algo = a;
        self
    }

    /// Select the density definition (builder form of
    /// [`ClusterSession::set_density_model`]).
    pub fn with_density_model(mut self, m: DensityModel) -> Self {
        self.density_model = m;
        self
    }

    /// Switch the density definition for subsequent `density()` calls. The
    /// per-(radius, model) artifact caches survive, so toggling between
    /// models re-stages at cache-hit price — the workflow behind
    /// EXPERIMENTS.md's cutoff-vs-knn-vs-kernel quality table.
    pub fn set_density_model(&mut self, m: DensityModel) {
        self.density_model = m;
    }

    pub fn density_model(&self) -> DensityModel {
        self.density_model
    }

    pub fn points(&self) -> &PointStore<S> {
        &self.pts
    }

    /// Does the session (and, once built, its kd-tree) still share the
    /// caller's coordinate allocation? Diagnostic for the no-clone
    /// contract; `true` whenever `other` is the store the session was built
    /// from (or any refcount sibling of it).
    pub fn shares_storage_with(&self, other: &PointStore<S>) -> bool {
        let tree_shares = self.tree.as_ref().map(|t| t.points().shares_storage(other)).unwrap_or(true);
        self.pts.shares_storage(other) && tree_shares
    }

    pub fn stats(&self) -> SessionStats {
        let mut s = self.stats;
        s.tree_shares_store =
            self.tree.as_ref().map(|t| t.points().shares_storage(&self.pts)).unwrap_or(true);
        s
    }

    /// Radius of the currently active density stage, if any.
    pub fn active_d_cut(&self) -> Option<f64> {
        self.active_stage.map(|(d, _)| d)
    }

    /// Artifact-cache key for a (radius, model) stage. `KnnRadius` densities
    /// do not depend on `d_cut` at all (d_k is ranked, not thresholded), so
    /// its radius component canonicalizes to zero — a radius sweep under the
    /// kNN model is all cache hits after the first computation, which is the
    /// whole point of the staged session.
    fn stage_key(d_cut: f64, model: DensityModel) -> (u64, DensityModel) {
        match model {
            DensityModel::KnnRadius { .. } => (0, model),
            _ => (d_cut.to_bits(), model),
        }
    }

    /// Step 1: ρ for every point at radius `d_cut` under the session's
    /// [`DensityModel`], cached per (radius, model). Switching either
    /// invalidates the active dependents stage (the per-key artifact cache
    /// keeps a later switch back cheap).
    pub fn density(&mut self, d_cut: f64) -> Result<Arc<Vec<u32>>, DpcError> {
        validate_d_cut(d_cut)?;
        let model = self.density_model;
        model.validate()?;
        let key = Self::stage_key(d_cut, model);
        if self.rho_cache.contains_key(&key) {
            self.stats.density_cache_hits += 1;
        } else {
            let t = Instant::now();
            let rho = match (model, self.density_algo) {
                (DensityModel::CutoffCount, DensityAlgo::TreePruned | DensityAlgo::TreeNoPrune) => {
                    let pts = &self.pts;
                    let tree = &*self.tree.get_or_insert_with(|| KdTree::build(pts));
                    let r_sq: S = radius_sq(d_cut);
                    let prune = self.density_algo == DensityAlgo::TreePruned;
                    parlay::par_map_grained(pts.len(), crate::dpc::QUERY_GRAIN, |i| {
                        let q = pts.point(i);
                        let c = if prune {
                            tree.range_count(q, r_sq, &mut NoStats)
                        } else {
                            tree.range_count_noprune(q, r_sq, &mut NoStats)
                        };
                        c as u32
                    })
                }
                (DensityModel::CutoffCount, other) => compute_density(&self.pts, d_cut, other),
                (_, DensityAlgo::Naive) => {
                    density::compute_density_model(&self.pts, d_cut, model, DensityAlgo::Naive)
                }
                // kNN/Gaussian on any tree-flavored algo: the session's
                // amortized tree (the ablation axes are cutoff-specific).
                _ => {
                    let pts = &self.pts;
                    let tree = &*self.tree.get_or_insert_with(|| KdTree::build(pts));
                    density::tree_model_density(pts, tree, d_cut, model)
                }
            };
            let secs = t.elapsed().as_secs_f64();
            self.rho_cache.insert(key, DensityArtifacts { rho: Arc::new(rho), secs });
            self.stats.density_computes += 1;
        }
        if self.active_stage.map(|(d, m)| Self::stage_key(d, m)) != Some(key) {
            // A genuinely different stage: the active dependents are stale.
            self.active_algo = None;
        }
        self.active_stage = Some((d_cut, model));
        // lint: allow(panic-surface) — the entry was inserted a few lines
        // up under the same &mut self borrow; no eviction can intervene.
        let cached = self.rho_cache.get(&key).expect("just ensured");
        Ok(Arc::clone(&cached.rho))
    }

    /// Step 2: the full (λ, δ) forest on top of the active density, cached
    /// per (radius, model, algorithm). Requires [`ClusterSession::density`]
    /// first.
    pub fn dependents(&mut self, algo: DepAlgo) -> Result<Arc<DepArtifacts>, DpcError> {
        let (d_cut, model) = self
            .active_stage
            .ok_or(DpcError::MissingStage { need: "density", call: "dependents" })?;
        let (stage_bits, _) = Self::stage_key(d_cut, model);
        let key = (stage_bits, model, algo);
        if let Some(art) = self.dep_cache.get(&key) {
            self.stats.dep_cache_hits += 1;
            self.active_algo = Some(algo);
            return Ok(Arc::clone(art));
        }
        let rho = Arc::clone(&self.rho_cache[&(stage_bits, model)].rho);
        let t = Instant::now();
        // rho_min = 0: compute every point's dependent so any later noise
        // threshold is a pure mask (candidate sets are threshold-free).
        let dep = dep::compute_dependents(&self.pts, &rho, 0.0, algo);
        let delta = dep::dependent_distances(&self.pts, &dep);
        let secs = t.elapsed().as_secs_f64();
        let art = Arc::new(DepArtifacts { dep, delta, secs });
        self.dep_cache.insert(key, Arc::clone(&art));
        self.stats.dep_computes += 1;
        self.active_algo = Some(algo);
        Ok(art)
    }

    /// Step 3 only: mask the cached forest by `rho_min` and run the
    /// union-find linkage. Requires both prior stages; byte-identical to a
    /// fresh full run at (active `d_cut`, `rho_min`, `delta_min`).
    pub fn cut(&self, rho_min: f64, delta_min: f64) -> Result<DpcResult, DpcError> {
        let (d_cut, model) =
            self.active_stage.ok_or(DpcError::MissingStage { need: "density", call: "cut" })?;
        let algo = self.active_algo.ok_or(DpcError::MissingStage { need: "dependents", call: "cut" })?;
        validate_thresholds(rho_min, delta_min)?;
        let params = DpcParams { d_cut, rho_min, delta_min, dtype: S::DTYPE, density: model };
        let (stage_bits, _) = Self::stage_key(d_cut, model);
        let density = &self.rho_cache[&(stage_bits, model)];
        let art = &self.dep_cache[&(stage_bits, model, algo)];
        let mut out = cut_cached(&self.pts, &density.rho, &art.dep, &art.delta, params);
        out.timings.density_s = density.secs;
        out.timings.dep_s = art.secs;
        Ok(out)
    }

    /// Convenience: run all three stages (hitting caches where possible) —
    /// the one-shot path that [`super::Dpc::run`] wraps. Adopts the params'
    /// density model.
    pub fn run(&mut self, params: DpcParams, algo: DepAlgo) -> Result<DpcResult, DpcError> {
        self.density_model = params.density;
        self.density(params.d_cut)?;
        self.dependents(algo)?;
        self.cut(params.rho_min, params.delta_min)
    }
}

/// Linkage-only execution against precomputed artifacts: mask the full
/// forest by `rho_min`, union non-center non-noise points with their
/// dependents, and assemble a [`DpcResult`]. Shared by
/// [`ClusterSession::cut`] and the coordinator's session-scoped recut jobs.
pub fn cut_cached<S: Scalar>(
    pts: &PointStore<S>,
    rho: &[u32],
    dep_full: &[Option<u32>],
    delta_full: &[f64],
    params: DpcParams,
) -> DpcResult {
    let n = pts.len();
    let t = Instant::now();
    let dep: Vec<Option<u32>> =
        parlay::par_map(n, |i| if (rho[i] as f64) < params.rho_min { None } else { dep_full[i] });
    let delta: Vec<f64> = parlay::par_map(n, |i| if dep[i].is_none() && dep_full[i].is_some() {
        f64::INFINITY
    } else {
        delta_full[i]
    });
    let link = linkage::single_linkage(pts, rho, &dep, params);
    let linkage_s = t.elapsed().as_secs_f64();
    DpcResult {
        rho: rho.to_vec(),
        dep,
        delta,
        labels: link.labels,
        centers: link.centers,
        num_clusters: link.num_clusters,
        num_noise: link.num_noise,
        timings: StepTimings { density_s: 0.0, dep_s: 0.0, linkage_s },
    }
}

/// Validate the input for one-shot entry points that skip session
/// construction (the coordinator's engine pipeline).
pub fn validate_points<S: Scalar>(pts: &PointStore<S>) -> Result<(), DpcError> {
    if pts.is_empty() {
        return Err(DpcError::EmptyInput);
    }
    pts.validate_finite()
}

pub fn validate_d_cut(d_cut: f64) -> Result<(), DpcError> {
    if !(d_cut.is_finite() && d_cut > 0.0) {
        return Err(DpcError::InvalidParam {
            name: "d_cut",
            value: d_cut,
            requirement: "must be positive and finite",
        });
    }
    Ok(())
}

pub fn validate_thresholds(rho_min: f64, delta_min: f64) -> Result<(), DpcError> {
    if rho_min.is_nan() || rho_min == f64::INFINITY {
        return Err(DpcError::InvalidParam {
            name: "rho_min",
            value: rho_min,
            requirement: "must not be NaN or +inf",
        });
    }
    if delta_min.is_nan() {
        return Err(DpcError::InvalidParam { name: "delta_min", value: delta_min, requirement: "must not be NaN" });
    }
    Ok(())
}

/// Validate a full parameter set (used by `Dpc::run` and the coordinator).
pub fn validate_params(params: &DpcParams) -> Result<(), DpcError> {
    validate_d_cut(params.d_cut)?;
    params.density.validate()?;
    validate_thresholds(params.rho_min, params.delta_min)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::{Dtype, PointSet};
    use crate::prng::SplitMix64;
    use crate::proputil::gen_clustered_points;

    fn blobs() -> PointSet {
        let mut rng = SplitMix64::new(71);
        gen_clustered_points(&mut rng, 400, 2, 3, 120.0, 2.0)
    }

    #[test]
    fn staged_calls_must_run_in_order() {
        let pts = blobs();
        let mut s = ClusterSession::build(&pts).unwrap();
        assert!(matches!(s.cut(0.0, 10.0), Err(DpcError::MissingStage { need: "density", .. })));
        assert!(matches!(s.dependents(DepAlgo::Priority), Err(DpcError::MissingStage { need: "density", .. })));
        s.density(4.0).unwrap();
        assert!(matches!(s.cut(0.0, 10.0), Err(DpcError::MissingStage { need: "dependents", .. })));
        s.dependents(DepAlgo::Priority).unwrap();
        assert!(s.cut(0.0, 10.0).is_ok());
    }

    #[test]
    fn build_rejects_empty_and_nonfinite() {
        assert!(matches!(ClusterSession::build(&PointSet::empty(2)), Err(DpcError::EmptyInput)));
        // Unvalidated generator path: `PointSet::new` rejects the NaN itself.
        let coords = [0.0, 0.0, f64::NAN, 1.0];
        let bad = PointSet::from_flat_fn(2, 2, |i| coords[i]);
        assert!(matches!(ClusterSession::build(&bad), Err(DpcError::NonFiniteCoordinate { point: 1, dim: 0 })));
    }

    #[test]
    fn density_rejects_bad_radius() {
        let pts = blobs();
        let mut s = ClusterSession::build(&pts).unwrap();
        for bad in [0.0, -3.0, f64::NAN, f64::INFINITY] {
            assert!(matches!(s.density(bad), Err(DpcError::InvalidParam { name: "d_cut", .. })), "{bad}");
        }
    }

    #[test]
    fn recut_reuses_cached_artifacts() {
        let pts = blobs();
        let mut s = ClusterSession::build(&pts).unwrap();
        s.density(4.0).unwrap();
        s.dependents(DepAlgo::Priority).unwrap();
        for (rho_min, delta_min) in [(0.0, 10.0), (2.0, 5.0), (1.0, 30.0), (0.0, f64::INFINITY)] {
            s.cut(rho_min, delta_min).unwrap();
        }
        let st = s.stats();
        assert_eq!(st.density_computes, 1);
        assert_eq!(st.dep_computes, 1);
    }

    #[test]
    fn session_shares_callers_buffer_without_copies() {
        let pts = blobs();
        let mut s = ClusterSession::build(&pts).unwrap();
        // Before the tree exists and after: always the caller's allocation.
        assert!(s.shares_storage_with(&pts));
        s.density(4.0).unwrap();
        s.dependents(DepAlgo::Priority).unwrap();
        s.cut(0.0, 10.0).unwrap();
        s.cut(2.0, 5.0).unwrap();
        assert!(s.shares_storage_with(&pts));
        assert!(s.stats().tree_shares_store);
        // A refcount sibling of the caller's store counts as sharing too.
        let sibling = pts.clone();
        assert!(s.shares_storage_with(&sibling));
    }

    #[test]
    fn f32_session_matches_oneshot_f32_run() {
        let pts64 = blobs();
        let pts = crate::geom::PointStore::<f32>::cast_from_f64(&pts64);
        let mut s = ClusterSession::build(&pts).unwrap();
        s.density(4.0).unwrap();
        s.dependents(DepAlgo::Fenwick).unwrap();
        let recut = s.cut(1.0, 8.0).unwrap();
        let params = DpcParams { d_cut: 4.0, rho_min: 1.0, delta_min: 8.0, dtype: Dtype::F32, ..DpcParams::default() };
        let fresh = crate::dpc::Dpc::new(params).dep_algo(DepAlgo::Fenwick).run(&pts).unwrap();
        assert_eq!(recut.rho, fresh.rho);
        assert_eq!(recut.dep, fresh.dep);
        assert_eq!(recut.delta, fresh.delta);
        assert_eq!(recut.labels, fresh.labels);
        assert!(s.shares_storage_with(&pts));
    }

    #[test]
    fn radius_switch_invalidates_deps_but_caches_by_radius() {
        let pts = blobs();
        let mut s = ClusterSession::build(&pts).unwrap();
        s.density(4.0).unwrap();
        s.dependents(DepAlgo::Priority).unwrap();
        s.density(6.0).unwrap();
        // New radius: dependents stage must be re-established.
        assert!(matches!(s.cut(0.0, 10.0), Err(DpcError::MissingStage { need: "dependents", .. })));
        s.dependents(DepAlgo::Priority).unwrap();
        s.cut(0.0, 10.0).unwrap();
        // Back to the first radius: both stages served from cache.
        s.density(4.0).unwrap();
        s.dependents(DepAlgo::Priority).unwrap();
        let st = s.stats();
        assert_eq!(st.density_computes, 2);
        assert_eq!(st.density_cache_hits, 1);
        assert_eq!(st.dep_computes, 2);
        assert_eq!(st.dep_cache_hits, 1);
    }

    #[test]
    fn model_switch_invalidates_stage_but_caches_per_model() {
        let pts = blobs();
        let mut s = ClusterSession::build(&pts).unwrap();
        s.density(4.0).unwrap();
        s.dependents(DepAlgo::Priority).unwrap();
        s.set_density_model(DensityModel::KnnRadius { k: 3 });
        s.density(4.0).unwrap();
        // Same radius, new model: the dependents stage must be re-staged.
        assert!(matches!(s.cut(0.0, 10.0), Err(DpcError::MissingStage { need: "dependents", .. })));
        s.dependents(DepAlgo::Priority).unwrap();
        s.cut(0.0, 10.0).unwrap();
        // Back to cutoff: both stages served from cache.
        s.set_density_model(DensityModel::CutoffCount);
        s.density(4.0).unwrap();
        s.dependents(DepAlgo::Priority).unwrap();
        let st = s.stats();
        assert_eq!(st.density_computes, 2);
        assert_eq!(st.density_cache_hits, 1);
        assert_eq!(st.dep_computes, 2);
        assert_eq!(st.dep_cache_hits, 1);
    }

    #[test]
    fn staged_model_runs_match_oneshot_runs() {
        let pts = blobs();
        for model in DensityModel::REPRESENTATIVE {
            let mut s = ClusterSession::build(&pts).unwrap().with_density_model(model);
            s.density(4.0).unwrap();
            s.dependents(DepAlgo::Fenwick).unwrap();
            let staged = s.cut(1.0, 8.0).unwrap();
            let params =
                DpcParams { d_cut: 4.0, rho_min: 1.0, delta_min: 8.0, density: model, ..DpcParams::default() };
            let fresh = crate::dpc::Dpc::new(params).dep_algo(DepAlgo::Fenwick).run(&pts).unwrap();
            assert_eq!(staged.rho, fresh.rho, "{model}");
            assert_eq!(staged.dep, fresh.dep, "{model}");
            assert_eq!(staged.delta, fresh.delta, "{model}");
            assert_eq!(staged.labels, fresh.labels, "{model}");
        }
    }

    #[test]
    fn knn_radius_sweep_is_all_cache_hits() {
        // d_k ranks do not depend on d_cut, so a radius sweep under the kNN
        // model computes each stage once and serves every later radius from
        // cache — without dropping the active dependents stage.
        let pts = blobs();
        let mut s = ClusterSession::build(&pts).unwrap().with_density_model(DensityModel::KnnRadius { k: 4 });
        s.density(2.0).unwrap();
        s.dependents(DepAlgo::Priority).unwrap();
        let first = s.cut(1.0, 8.0).unwrap();
        for d_cut in [3.0, 7.5, 2.0] {
            let rho = s.density(d_cut).unwrap();
            assert_eq!(*rho, first.rho, "knn rho is radius-independent");
            // The dependents stage survived the radius switch.
            let again = s.cut(1.0, 8.0).unwrap();
            assert_eq!(again.labels, first.labels);
        }
        let st = s.stats();
        assert_eq!(st.density_computes, 1);
        assert_eq!(st.density_cache_hits, 3);
        assert_eq!(st.dep_computes, 1);
    }

    #[test]
    fn knn_density_rejects_zero_k() {
        let pts = blobs();
        let mut s = ClusterSession::build(&pts).unwrap().with_density_model(DensityModel::KnnRadius { k: 0 });
        assert!(matches!(s.density(4.0), Err(DpcError::InvalidParam { name: "k", .. })));
    }

    #[test]
    fn tree_density_variants_match_oneshot_compute() {
        let pts = blobs();
        for algo in [DensityAlgo::TreePruned, DensityAlgo::TreeNoPrune, DensityAlgo::Naive] {
            let mut s = ClusterSession::build(&pts).unwrap().with_density_algo(algo);
            let rho = s.density(5.0).unwrap();
            assert_eq!(*rho, compute_density(&pts, 5.0, algo), "{algo:?}");
        }
    }
}
