//! DPC-APPROX-BASELINE: a grid-based *approximate* DPC in the style of
//! Amagata–Hara [3]'s fastest approximate algorithm, reimplemented as the
//! comparison baseline for Table 3 / Figure 3.
//!
//! A uniform grid with cell side `d_cut / √d` is laid over the points (any
//! two points in one cell are within `d_cut`). The grid *shares* work across
//! co-located points:
//!
//! - **Density**: one count per cell — all points of every cell whose
//!   centroid lies within `d_cut` of this cell's centroid — shared by all of
//!   the cell's members.
//! - **Dependent points**: cell-granular priorities (cell density, id
//!   tiebreak); each point searches same-cell higher-priority points, then
//!   expanding Chebyshev rings of cells, stopping when the ring lower bound
//!   exceeds the best candidate.
//!
//! The ring enumeration costs O((2r+1)^d − (2r−1)^d) cells per ring, which
//! reproduces the baseline's characteristic blowups: sparse/skewed data
//! (varden) forces wide ring expansion, and high dimension (HT, d = 8) makes
//! each ring exponentially wide — exactly the datasets where the paper
//! reports DPC-APPROX-BASELINE losing by orders of magnitude.

use std::collections::HashMap;
use std::time::Instant;

use crate::dpc::{linkage, DpcParams, DpcResult, StepTimings};
use crate::geom::PointSet;
use crate::parlay;

struct Grid {
    /// cell index per point.
    cell_of: Vec<u32>,
    /// points per cell.
    members: Vec<Vec<u32>>,
    /// integer cell coordinates per cell.
    coords: Vec<Vec<i64>>,
    /// cell lookup.
    index: HashMap<Vec<i64>, u32>,
    side: f64,
    d: usize,
}

impl Grid {
    fn build(pts: &PointSet, d_cut: f64) -> Self {
        let d = pts.dim();
        let side = d_cut / (d as f64).sqrt();
        let mut index: HashMap<Vec<i64>, u32> = HashMap::new();
        let mut members: Vec<Vec<u32>> = Vec::new();
        let mut coords: Vec<Vec<i64>> = Vec::new();
        let mut cell_of = vec![0u32; pts.len()];
        for i in 0..pts.len() {
            let key: Vec<i64> = (0..d).map(|k| (pts.coord(i, k) / side).floor() as i64).collect();
            let id = *index.entry(key.clone()).or_insert_with(|| {
                members.push(Vec::new());
                coords.push(key);
                (members.len() - 1) as u32
            });
            members[id as usize].push(i as u32);
            cell_of[i] = id;
        }
        Grid { cell_of, members, coords, index, side, d }
    }

    fn centroid(&self, c: u32) -> Vec<f64> {
        self.coords[c as usize].iter().map(|&v| (v as f64 + 0.5) * self.side).collect()
    }

    /// Visit every existing cell whose integer coords differ from `base` by
    /// at most `r` in Chebyshev distance, with exactly-`r` ring filtering.
    fn for_ring<F: FnMut(u32)>(&self, base: &[i64], r: i64, f: &mut F) {
        let mut offset = vec![0i64; self.d];
        self.ring_rec(base, r, 0, false, &mut offset, f);
    }

    fn ring_rec<F: FnMut(u32)>(&self, base: &[i64], r: i64, k: usize, any_extreme: bool, offset: &mut Vec<i64>, f: &mut F) {
        if k == self.d {
            if r == 0 || any_extreme {
                let key: Vec<i64> = (0..self.d).map(|j| base[j] + offset[j]).collect();
                if let Some(&c) = self.index.get(&key) {
                    f(c);
                }
            }
            return;
        }
        for o in -r..=r {
            offset[k] = o;
            self.ring_rec(base, r, k + 1, any_extreme || o.abs() == r, offset, f);
        }
    }
}

/// Approximate densities: per-cell shared counts.
fn approx_density(pts: &PointSet, grid: &Grid, d_cut: f64) -> Vec<u32> {
    let ncells = grid.members.len();
    // Max Chebyshev ring whose centroids can be within d_cut: ceil(√d) + 1.
    let max_r = (d_cut / grid.side).ceil() as i64 + 1;
    let cell_rho: Vec<u32> = parlay::par_map_grained(ncells, crate::dpc::QUERY_GRAIN, |c| {
        let cen = grid.centroid(c as u32);
        let mut count = 0u32;
        for r in 0..=max_r {
            grid.for_ring(&grid.coords[c], r, &mut |c2| {
                let cen2 = grid.centroid(c2);
                if crate::geom::dist_sq(&cen, &cen2) <= d_cut * d_cut {
                    count += grid.members[c2 as usize].len() as u32;
                }
            });
        }
        count
    });
    parlay::par_map(pts.len(), |i| cell_rho[grid.cell_of[i] as usize])
}

/// Widest grid extent in cells (bounds the ring expansion).
fn grid_max_extent(grid: &Grid) -> i64 {
    let mut lo = vec![i64::MAX; grid.d];
    let mut hi = vec![i64::MIN; grid.d];
    for c in &grid.coords {
        for k in 0..grid.d {
            lo[k] = lo[k].min(c[k]);
            hi[k] = hi[k].max(c[k]);
        }
    }
    (0..grid.d).map(|k| hi[k] - lo[k]).max().unwrap_or(0) + 1
}

/// Expanding-ring approximate dependent search for one point.
fn approx_dependent_one(
    pts: &PointSet,
    grid: &Grid,
    rho: &[u32],
    rho_min: f64,
    i: usize,
    max_extent: i64,
) -> Option<u32> {
    approx_dependent_one_deadline(pts, grid, rho, rho_min, i, max_extent, None)
}

/// As above, with an optional (start, budget_s) deadline checked per ring —
/// a single isolated point can otherwise expand rings across the whole grid
/// for longer than the entire budget.
#[allow(clippy::too_many_arguments)]
fn approx_dependent_one_deadline(
    pts: &PointSet,
    grid: &Grid,
    rho: &[u32],
    rho_min: f64,
    i: usize,
    max_extent: i64,
    deadline: Option<(Instant, f64)>,
) -> Option<u32> {
    if (rho[i] as f64) < rho_min {
        return None;
    }
    let q = pts.point(i);
    let gi = (rho[i], u32::MAX - i as u32);
    let mut best: (u32, f64) = (u32::MAX, f64::INFINITY);
    let base = &grid.coords[grid.cell_of[i] as usize];
    for r in 0..=max_extent {
        if let Some((start, budget)) = deadline {
            if r % 16 == 0 && start.elapsed().as_secs_f64() > budget {
                return None; // result discarded; run is being cancelled
            }
        }
        // Ring lower bound: cells at Chebyshev ring r are ≥ (r-1)·side
        // away from any point of the base cell.
        let bound = ((r - 1).max(0)) as f64 * grid.side;
        if best.0 != u32::MAX && bound * bound > best.1 {
            break;
        }
        grid.for_ring(base, r, &mut |c2| {
            for &j in &grid.members[c2 as usize] {
                let gj = (rho[j as usize], u32::MAX - j);
                if gj <= gi {
                    continue;
                }
                let ds = pts.dist_sq_to(j as usize, q);
                if ds < best.1 || (ds == best.1 && j < best.0) {
                    best = (j, ds);
                }
            }
        });
    }
    if best.0 == u32::MAX {
        None
    } else {
        Some(best.0)
    }
}

/// Approximate dependent points via expanding ring search.
fn approx_dependents(pts: &PointSet, grid: &Grid, rho: &[u32], rho_min: f64) -> Vec<Option<u32>> {
    let n = pts.len();
    let max_extent = grid_max_extent(grid);
    // Ring-expansion cost is heavily skewed (isolated points scan far), so
    // use the fine query grain and let the stealer balance.
    parlay::par_map_grained(n, crate::dpc::QUERY_GRAIN, |i| {
        approx_dependent_one(pts, grid, rho, rho_min, i, max_extent)
    })
}

/// Budgeted variant for the benches: returns `None` (the analog of the
/// paper's "did not terminate within 48 hours" entries) when a cheap
/// projection says the run would exceed `budget_s` seconds.
///
/// Projection: (a) the density step's ring enumeration is
/// ~`ncells · (2·ceil(d_cut/side)+3)^d` cell visits — reject if > 2e9;
/// (b) the dependent step is timed on a ~256-point sample and extrapolated
/// linearly (ring expansion cost is per-point and roughly iid across the
/// sample).
pub fn run_approx_budgeted(pts: &PointSet, params: DpcParams, budget_s: f64) -> Option<DpcResult> {
    let d = pts.dim() as i32;
    let side = params.d_cut / (pts.dim() as f64).sqrt();
    let ring_cells = (2.0 * (params.d_cut / side).ceil() + 3.0).powi(d);
    if (pts.len() as f64) * ring_cells > 2.0e9 {
        return None;
    }
    let mut timings = StepTimings::default();
    let t0 = Instant::now();
    let grid = Grid::build(pts, params.d_cut);
    let rho = approx_density(pts, &grid, params.d_cut);
    timings.density_s = t0.elapsed().as_secs_f64();
    if timings.density_s > budget_s {
        return None;
    }

    // Sample-based projection of the dep step. The sample loop itself is
    // deadline-checked (on pathological data even a handful of ring
    // expansions can be very slow — which is precisely the signal).
    let n = pts.len();
    let sample = 256.min(n);
    let step = (n / sample).max(1);
    let max_extent = grid_max_extent(&grid);
    let t_s = Instant::now();
    let sample_deadline = (budget_s / 10.0).max(0.5);
    let mut sampled = 0usize;
    for i in (0..n).step_by(step) {
        std::hint::black_box(approx_dependent_one_deadline(
            pts, &grid, &rho, params.rho_min, i, max_extent,
            Some((t_s, sample_deadline)),
        ));
        sampled += 1;
        if t_s.elapsed().as_secs_f64() > sample_deadline {
            break;
        }
    }
    let projected = t_s.elapsed().as_secs_f64() * (n as f64 / sampled as f64);
    if projected > budget_s {
        return None;
    }

    // Mean-based projection can still underestimate a heavy tail (a few
    // isolated points whose rings expand across the whole grid — exactly
    // the varden/GeoLife pathology), so the full run also carries a hard
    // in-flight deadline.
    let t1 = Instant::now();
    use std::sync::atomic::{AtomicBool, Ordering};
    let cancelled = AtomicBool::new(false);
    let deadline = Instant::now();
    let dep: Vec<Option<u32>> = parlay::par_map_grained(n, crate::dpc::QUERY_GRAIN, |i| {
        // relaxed: advisory cancellation flag — a stale read only delays the
        // bail-out by one item; the join below is the synchronization point.
        if cancelled.load(Ordering::Relaxed) {
            return None;
        }
        if deadline.elapsed().as_secs_f64() > budget_s {
            // relaxed: idempotent one-way flag; ordering of the store is
            // irrelevant because every racer writes the same value.
            cancelled.store(true, Ordering::Relaxed);
            return None;
        }
        approx_dependent_one_deadline(pts, &grid, &rho, params.rho_min, i, max_extent, Some((deadline, budget_s)))
    });
    // relaxed: read after the par_map join, which already synchronizes all
    // worker writes with this thread.
    if cancelled.load(Ordering::Relaxed) {
        return None;
    }
    timings.dep_s = t1.elapsed().as_secs_f64();

    let t2 = Instant::now();
    let link = linkage::single_linkage(pts, &rho, &dep, params);
    timings.linkage_s = t2.elapsed().as_secs_f64();
    let delta = crate::dpc::dep::dependent_distances(pts, &dep);
    Some(DpcResult {
        rho,
        dep,
        delta,
        labels: link.labels,
        centers: link.centers,
        num_clusters: link.num_clusters,
        num_noise: link.num_noise,
        timings,
    })
}

/// Run the approximate grid-based DPC pipeline end to end.
pub fn run_approx(pts: &PointSet, params: DpcParams) -> DpcResult {
    assert!(!pts.is_empty());
    let mut timings = StepTimings::default();
    let t0 = Instant::now();
    let grid = Grid::build(pts, params.d_cut);
    let rho = approx_density(pts, &grid, params.d_cut);
    timings.density_s = t0.elapsed().as_secs_f64();

    let t1 = Instant::now();
    let dep = approx_dependents(pts, &grid, &rho, params.rho_min);
    timings.dep_s = t1.elapsed().as_secs_f64();

    let t2 = Instant::now();
    let link = linkage::single_linkage(pts, &rho, &dep, params);
    timings.linkage_s = t2.elapsed().as_secs_f64();

    let delta = crate::dpc::dep::dependent_distances(pts, &dep);
    DpcResult {
        rho,
        dep,
        delta,
        labels: link.labels,
        centers: link.centers,
        num_clusters: link.num_clusters,
        num_noise: link.num_noise,
        timings,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpc::{Dpc, DepAlgo};
    use crate::metrics::adjusted_rand_index;
    use crate::prng::SplitMix64;

    fn two_blobs(rng: &mut SplitMix64) -> PointSet {
        let mut coords = Vec::new();
        for _ in 0..150 {
            coords.push(rng.uniform(0.0, 5.0));
            coords.push(rng.uniform(0.0, 5.0));
        }
        for _ in 0..150 {
            coords.push(rng.uniform(60.0, 65.0));
            coords.push(rng.uniform(60.0, 65.0));
        }
        PointSet::new(coords, 2)
    }

    #[test]
    fn grid_assigns_every_point() {
        let mut rng = SplitMix64::new(71);
        let pts = two_blobs(&mut rng);
        let g = Grid::build(&pts, 3.0);
        let total: usize = g.members.iter().map(|m| m.len()).sum();
        assert_eq!(total, pts.len());
        // Any two points in one cell are within d_cut.
        for (c, members) in g.members.iter().enumerate() {
            for &a in members {
                for &b in members {
                    assert!(pts.dist_sq(a as usize, b as usize) <= 3.0 * 3.0 + 1e-9, "cell {c}");
                }
            }
        }
    }

    #[test]
    fn ring_zero_is_base_cell_only() {
        let mut rng = SplitMix64::new(72);
        let pts = two_blobs(&mut rng);
        let g = Grid::build(&pts, 3.0);
        let mut seen = Vec::new();
        g.for_ring(&g.coords[0], 0, &mut |c| seen.push(c));
        assert_eq!(seen, vec![0]);
    }

    #[test]
    fn rings_partition_neighborhood() {
        let mut rng = SplitMix64::new(73);
        let pts = two_blobs(&mut rng);
        let g = Grid::build(&pts, 3.0);
        // Union of rings 0..=R must equal all cells within Chebyshev R.
        let mut seen = std::collections::HashSet::new();
        for r in 0..=3i64 {
            g.for_ring(&g.coords[0], r, &mut |c| {
                assert!(seen.insert(c), "cell {c} visited twice");
            });
        }
        for (c, coord) in g.coords.iter().enumerate() {
            let cheb = (0..g.d).map(|k| (coord[k] - g.coords[0][k]).abs()).max().unwrap();
            assert_eq!(seen.contains(&(c as u32)), cheb <= 3);
        }
    }

    #[test]
    fn approx_clusters_well_separated_blobs_like_exact() {
        let mut rng = SplitMix64::new(74);
        let pts = two_blobs(&mut rng);
        let params = DpcParams { d_cut: 3.0, rho_min: 0.0, delta_min: 20.0, ..DpcParams::default() };
        let exact = Dpc::new(params).dep_algo(DepAlgo::Priority).run(&pts).unwrap();
        let approx = run_approx(&pts, params);
        assert_eq!(exact.num_clusters, 2);
        assert_eq!(approx.num_clusters, 2);
        let ari = adjusted_rand_index(&exact.labels, &approx.labels);
        assert!(ari > 0.99, "ARI {ari}");
    }

    #[test]
    fn approx_density_close_to_exact_on_uniform() {
        let mut rng = SplitMix64::new(75);
        let pts = crate::proputil::gen_uniform_points(&mut rng, 500, 2, 40.0);
        let params = DpcParams { d_cut: 5.0, rho_min: 0.0, delta_min: 10.0, ..DpcParams::default() };
        let exact_rho = crate::dpc::compute_density(&pts, params.d_cut, crate::dpc::DensityAlgo::TreePruned);
        let grid = Grid::build(&pts, params.d_cut);
        let approx_rho = approx_density(&pts, &grid, params.d_cut);
        // Mean relative error should be moderate (it's an approximation).
        let mre: f64 = (0..500)
            .map(|i| ((approx_rho[i] as f64 - exact_rho[i] as f64) / exact_rho[i].max(1) as f64).abs())
            .sum::<f64>()
            / 500.0;
        assert!(mre < 0.6, "mean relative error {mre}");
    }
}
