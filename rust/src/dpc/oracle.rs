//! Sequential O(n²) brute-force DPC oracle — the independent reference the
//! differential suite (`rust/tests/oracle_differential.rs`) holds every
//! (DensityModel × DepAlgo) pipeline against, byte for byte.
//!
//! Everything here is deliberately the *dumbest correct implementation*:
//! all-pairs scans, no trees, no parallelism, no caches. Where the pipeline
//! sorts/ranks/prunes, the oracle counts; where the pipeline unions in
//! parallel, the oracle follows dependency chains one hop at a time. The
//! only shared code is [`super::density::pair_weight`] (backed by
//! [`super::gaussian_weight`] / [`super::density::epanechnikov_weight`]) and
//! [`crate::geom::radius_sq`] — those functions *define* the kernel models
//! and "the radius at precision S", so an oracle that reimplemented them
//! would be testing a different specification, not the same one.
//!
//! Used only by tests and benches; nothing in the serving path calls it.

use crate::geom::{radius_sq, PointStore, Scalar};

use super::density::{pair_weight, saturate_rho};
use super::{priority_key, DensityModel, DpcParams, DpcResult, StepTimings};

/// Brute-force Step 1 under any [`DensityModel`].
pub fn oracle_density<S: Scalar>(pts: &PointStore<S>, d_cut: f64, model: DensityModel) -> Vec<u32> {
    let n = pts.len();
    let r_sq: S = radius_sq(d_cut);
    match model {
        DensityModel::CutoffCount => (0..n)
            .map(|i| (0..n).filter(|&j| pts.dist_sq(i, j) <= r_sq).count() as u32)
            .collect(),
        DensityModel::KnnRadius { k } => {
            // d_k by full sort per point (the pipeline selects; the oracle
            // sorts — different code, same value), then the rank by direct
            // counting (the pipeline ranks via one global sort).
            let k = k as usize;
            let dk: Vec<S> = (0..n)
                .map(|i| {
                    let mut ds: Vec<S> =
                        (0..n).filter(|&j| j != i).map(|j| pts.dist_sq(i, j)).collect();
                    // lint: allow(panic-surface) — distances over
                    // ingest-validated finite coordinates are never NaN.
                    ds.sort_by(|a, b| a.partial_cmp(b).unwrap());
                    if ds.len() < k {
                        S::INFINITY
                    } else {
                        ds[k - 1]
                    }
                })
                .collect();
            (0..n)
                .map(|i| (0..n).filter(|&j| dk[j] > dk[i]).count() as u32)
                .collect()
        }
        DensityModel::GaussianKernel | DensityModel::Epanechnikov => {
            let inv = 1.0 / (d_cut * d_cut);
            (0..n)
                .map(|i| {
                    let sum: u64 = (0..n)
                        .map(|j| pts.dist_sq(i, j))
                        .filter(|&ds| ds <= r_sq)
                        .map(|ds| pair_weight(model, ds.to_f64(), inv))
                        .sum();
                    saturate_rho(sum)
                })
                .collect()
        }
    }
}

/// Brute-force Steps 2–3 on a given ρ, mirroring the masked-forest
/// semantics every pipeline entry point produces: noise points get no λ and
/// an ∞ δ; everyone else takes the nearest strictly-higher-priority point
/// (ties by smaller id).
fn oracle_dependents<S: Scalar>(
    pts: &PointStore<S>,
    rho: &[u32],
    rho_min: f64,
) -> (Vec<Option<u32>>, Vec<f64>) {
    let n = pts.len();
    let gamma: Vec<u64> = rho.iter().enumerate().map(|(i, &r)| priority_key(r, i as u32)).collect();
    let mut dep = vec![None; n];
    let mut delta = vec![f64::INFINITY; n];
    for i in 0..n {
        if (rho[i] as f64) < rho_min {
            continue;
        }
        let mut best: Option<(u32, S)> = None;
        for j in 0..n {
            if gamma[j] <= gamma[i] {
                continue;
            }
            let ds = pts.dist_sq(i, j);
            match best {
                Some((bj, bd)) if ds > bd || (ds == bd && j as u32 > bj) => {}
                _ => best = Some((j as u32, ds)),
            }
        }
        if let Some((j, ds)) = best {
            dep[i] = Some(j);
            // The one widening sqrt, same formula as `dep::dependent_distances`.
            delta[i] = ds.to_f64().sqrt();
        }
    }
    (dep, delta)
}

/// The full sequential reference pipeline: Steps 1–3 under
/// `params.density`, producing a [`DpcResult`] field-compatible with every
/// parallel pipeline (timings zeroed — the oracle measures correctness).
pub fn oracle_pipeline<S: Scalar>(pts: &PointStore<S>, params: DpcParams) -> DpcResult {
    let n = pts.len();
    let rho = oracle_density(pts, params.d_cut, params.density);
    let (dep, delta) = oracle_dependents(pts, &rho, params.rho_min);

    let is_noise: Vec<bool> = (0..n).map(|i| (rho[i] as f64) < params.rho_min).collect();
    let is_center: Vec<bool> =
        (0..n).map(|i| !is_noise[i] && delta[i] >= params.delta_min).collect();
    // Label by walking the dependency chain to its first center. Chains
    // ascend strictly in priority, so they terminate; the global peak
    // (λ = None) has δ = ∞ and is always a center, so every non-noise
    // chain ends on one.
    let labels: Vec<i64> = (0..n)
        .map(|i| {
            if is_noise[i] {
                return -1;
            }
            let mut cur = i;
            while !is_center[cur] {
                // lint: allow(panic-surface) — Algorithm 1 invariant: every
                // non-center, non-noise point has a dependent by definition.
                cur = dep[cur].expect("non-center non-noise point must have a dependent") as usize;
            }
            cur as i64
        })
        .collect();
    let centers: Vec<u32> = (0..n as u32).filter(|&i| is_center[i as usize]).collect();
    let num_noise = is_noise.iter().filter(|&&x| x).count();
    DpcResult {
        rho,
        dep,
        delta,
        num_clusters: centers.len(),
        centers,
        labels,
        num_noise,
        timings: StepTimings::default(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpc::{DepAlgo, Dpc};
    use crate::geom::PointSet;
    use crate::proputil::gen_clustered_points;
    use crate::prng::SplitMix64;

    #[test]
    fn oracle_matches_pipeline_on_a_smoke_case() {
        let mut rng = SplitMix64::new(151);
        let pts = gen_clustered_points(&mut rng, 120, 2, 3, 60.0, 2.0);
        for model in DensityModel::REPRESENTATIVE {
            let params = DpcParams {
                d_cut: 4.0,
                rho_min: 2.0,
                delta_min: 8.0,
                density: model,
                ..DpcParams::default()
            };
            let want = oracle_pipeline(&pts, params);
            let got = Dpc::new(params).dep_algo(DepAlgo::Priority).run(&pts).unwrap();
            assert_eq!(got.rho, want.rho, "{model}: rho");
            assert_eq!(got.dep, want.dep, "{model}: dep");
            assert_eq!(got.delta, want.delta, "{model}: delta");
            assert_eq!(got.labels, want.labels, "{model}: labels");
            assert_eq!(got.centers, want.centers, "{model}: centers");
        }
    }

    #[test]
    fn oracle_handles_single_point_and_all_noise() {
        let pts = PointSet::new(vec![1.0, 2.0], 2);
        let out = oracle_pipeline(&pts, DpcParams { d_cut: 1.0, delta_min: 5.0, ..DpcParams::default() });
        assert_eq!(out.rho, vec![1]);
        assert_eq!(out.dep, vec![None]);
        assert_eq!(out.labels, vec![0]);
        assert_eq!((out.num_clusters, out.num_noise), (1, 0));

        let out = oracle_pipeline(
            &pts,
            DpcParams { d_cut: 1.0, rho_min: 10.0, delta_min: 5.0, ..DpcParams::default() },
        );
        assert_eq!(out.labels, vec![-1]);
        assert_eq!((out.num_clusters, out.num_noise), (0, 1));
    }
}
