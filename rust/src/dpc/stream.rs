//! Streaming ingestion: a logarithmic kd-forest session that absorbs point
//! batches without a from-scratch rebuild, while staying **exact**.
//!
//! A [`StreamingSession`] maintains the paper's Step-1/Step-2 artifacts
//! (ρ, λ, δ) under insertion-only growth:
//!
//! - **Index**: a Bentley–Saxe merge forest. The point count's binary
//!   representation decides the structure — one static [`KdTree`] per set
//!   bit, of exactly 2^k points. An ingest merges only the levels whose bit
//!   flipped (plus the batch) and rebuilds one tree per gained bit, so each
//!   point is rebuilt O(log n) times over the session's lifetime
//!   ([`StreamStats::tree_points_built`] is the observable bound). Every
//!   query aggregates over ≤ log₂ n trees; which tree holds which point
//!   never affects results — counts and NN minima are partition-independent.
//! - **ρ repair** (exact, both directions): each batch point range-counts
//!   the pre-merge forest plus a throwaway batch tree for its own ρ, and
//!   range-*reports* the old forest so every old point within `d_cut` of an
//!   inserted point gets its integer count bumped. Under the fixed-point
//!   Gaussian model the "count" generalizes to a commutative integer weight
//!   sum — same repair, same exactness. The non-monotone kNN-rank model
//!   instead recomputes its queries over the merged forest (exact, with the
//!   index still amortized; see [`super::DensityModel`]).
//! - **λ/δ repair** (exact): priorities (ρ with the id tiebreak) only ever
//!   increase, so a point's dependent can change in just two ways. If its
//!   cached dependent still outranks it, the candidate set kept its old
//!   minimum and only *gained* members — all from the batch or from
//!   ρ-bumped old points — so the cached (λ, δ) races a small kd-tree over
//!   exactly that priority-increased set, seeded at the old δ. Otherwise
//!   (new points, and old points whose dependent no longer outranks them)
//!   a full priority-filtered NN runs over the forest.
//!
//! The invariant that makes this shippable: after every `ingest`, (ρ, λ, δ)
//! — and any [`StreamingSession::cut`] — are **byte-identical** to a fresh
//! [`super::ClusterSession`] built on the concatenated point set, for all
//! five [`super::DepAlgo`]s (they agree with each other by the paper's
//! exactness invariant, so the streaming path is algorithm-independent).
//! `rust/tests/conformance.rs` enforces it — at both precisions;
//! `benches/stream_ingest.rs` measures the ingest-vs-rebuild win.
//!
//! Storage: every level tree pins the [`PointStore`] snapshot it was built
//! against **by refcount** (the store's `Arc<[S]>` buffer). An ingest
//! allocates one new concatenated buffer (unavoidable growth); the repair
//! passes and all rebuilt trees then share it — no defensive snapshot
//! copies, and no `unsafe` lifetime extension (the pre-generic code
//! transmuted a borrowed tree to `'static`; an owning tree makes that
//! machinery vanish). Worst-case pinned memory is O(n log n) coordinates,
//! the same bound as the Fenwick structure's block trees. And while the
//! *heavy* work (tree rebuilds, range counts, full priority-NN queries) is
//! confined to the batch and its neighborhood, each ingest still makes O(n)
//! cheap passes (the bump array and one pruned seeded race per retained
//! point), so the win over a full rebuild is the constant-factor gap
//! between a pruned race and a full pipeline — large (see
//! `benches/stream_ingest.rs`), but tiny per-point batches over huge
//! sessions should be coalesced by the caller.

use std::sync::atomic::{AtomicU64, Ordering as AtomicOrdering};
use std::time::Instant;

use crate::error::DpcError;
use crate::geom::{radius_sq, PointStore, Scalar};
use crate::kdtree::{KdTree, NoStats};
use crate::parlay;

use super::density::{knn_rank_densities, pair_weight, saturate_rho};
use super::{priority_key, session, DensityModel, DpcParams, DpcResult};

/// One forest level: a static kd-tree over exactly 2^k of the session's
/// points. The tree owns a refcount share of the coordinate snapshot it was
/// built against, so the session's store may grow (allocate a new buffer)
/// without invalidating preserved levels.
struct OwnedLevel<S: Scalar> {
    k: u32,
    /// Global point ids this level owns (also in the tree's permutation;
    /// kept separately so merges can reclaim them without tree accessors).
    ids: Vec<u32>,
    tree: KdTree<S>,
}

impl<S: Scalar> OwnedLevel<S> {
    fn build(snapshot: &PointStore<S>, k: u32, ids: Vec<u32>) -> Self {
        debug_assert_eq!(ids.len(), 1usize << k);
        let tree = KdTree::build_from_ids(snapshot, ids.clone());
        OwnedLevel { k, ids, tree }
    }
}

/// Compute/repair counters — the observable proof that ingests do
/// logarithmic rebuild work and repair (rather than recompute) the
/// dependency forest. Mirrors [`super::SessionStats`].
#[derive(Clone, Copy, Debug, Default)]
pub struct StreamStats {
    pub ingests: u64,
    pub points_ingested: u64,
    /// kd-trees (re)built across all merges and the total points fed into
    /// them: after n single-point ingests the latter is O(n log n), vs the
    /// Θ(n²) a rebuild-per-ingest design would pay.
    pub trees_built: u64,
    pub tree_points_built: u64,
    /// Old points whose ρ a batch bumped (their priorities moved).
    pub rho_bumped: u64,
    /// Step-2 repair split: full forest priority-NN re-queries vs cheap
    /// races of a cached dependent against the priority-increased set.
    pub dep_full_queries: u64,
    pub dep_seeded_races: u64,
    /// Points whose (λ, δ) actually changed, across all ingests.
    pub dep_changed: u64,
    /// Cumulative wall-clock seconds in Step-1 / Step-2 repair.
    pub rho_secs: f64,
    pub dep_secs: f64,
}

/// An incremental, exact clustering session over a growing point set.
/// Generic over the coordinate [`Scalar`] — the constructor has no
/// store-typed argument, so name the precision at the call site
/// (`StreamingSession::<f32>::new(..)`).
///
/// ```no_run
/// use parcluster::dpc::stream::StreamingSession;
/// use parcluster::datasets::synthetic;
///
/// let pts = synthetic::uniform(10_000, 2, 1000.0, 42);
/// let mut s = StreamingSession::<f64>::new(2, 30.0)?;
/// s.ingest(&pts)?;                  // first batch: builds the forest
/// s.ingest(&pts)?;                  // later batches: amortized repair
/// let out = s.cut(0.0, 100.0)?;     // identical to a from-scratch session
/// println!("{} clusters", out.num_clusters);
/// # Ok::<(), parcluster::error::DpcError>(())
/// ```
pub struct StreamingSession<S: Scalar = f64> {
    d_cut: f64,
    /// The density definition the session maintains ρ under. Monotone
    /// models (cutoff, Gaussian) take the incremental repair path; the
    /// kNN-rank model — whose ρ can *decrease* for third parties when a
    /// batch shrinks someone's k-NN radius — recomputes (ρ, λ, δ) over the
    /// forest per ingest instead (exact either way; see `dpc::density`).
    model: DensityModel,
    pts: PointStore<S>,
    /// Invariant: distinct `k`s, descending — the binary representation of
    /// `pts.len()`.
    levels: Vec<OwnedLevel<S>>,
    rho: Vec<u32>,
    /// `priority_key(rho[i], i)` per point, maintained in place: an ingest
    /// rewrites only the raised entries instead of rebuilding the array.
    gamma: Vec<u64>,
    /// Full (`rho_min = 0`) dependency forest, exactly as
    /// [`super::DepArtifacts`] would hold it.
    dep: Vec<Option<u32>>,
    delta: Vec<f64>,
    stats: StreamStats,
}

impl<S: Scalar> std::fmt::Debug for StreamingSession<S> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("StreamingSession")
            .field("len", &self.pts.len())
            .field("d_cut", &self.d_cut)
            .field("model", &self.model)
            .field("levels", &self.levels.len())
            .finish_non_exhaustive()
    }
}

impl<S: Scalar> StreamingSession<S> {
    /// Open an empty session at a fixed density radius, under the paper's
    /// cutoff-count density. The radius is part of the maintained state
    /// (ρ is relative to it), so it cannot change mid-stream — open a new
    /// session for a new radius.
    pub fn new(dim: usize, d_cut: f64) -> Result<Self, DpcError> {
        Self::new_with_model(dim, d_cut, DensityModel::CutoffCount)
    }

    /// Open an empty session under any [`DensityModel`]. Like the radius,
    /// the model is part of the maintained state and fixed for the
    /// session's lifetime.
    pub fn new_with_model(dim: usize, d_cut: f64, model: DensityModel) -> Result<Self, DpcError> {
        if dim == 0 {
            return Err(DpcError::InvalidParam { name: "dim", value: 0.0, requirement: "must be positive" });
        }
        session::validate_d_cut(d_cut)?;
        model.validate()?;
        Ok(StreamingSession {
            d_cut,
            model,
            pts: PointStore::empty(dim),
            levels: Vec::new(),
            rho: Vec::new(),
            gamma: Vec::new(),
            dep: Vec::new(),
            delta: Vec::new(),
            stats: StreamStats::default(),
        })
    }

    pub fn d_cut(&self) -> f64 {
        self.d_cut
    }

    pub fn density_model(&self) -> DensityModel {
        self.model
    }

    pub fn len(&self) -> usize {
        self.pts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pts.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.pts.dim()
    }

    /// All points ingested so far, in ingest order (ids are stable).
    pub fn points(&self) -> &PointStore<S> {
        &self.pts
    }

    /// ρ per point at the session radius.
    pub fn rho(&self) -> &[u32] {
        &self.rho
    }

    /// λ per point (`None` only for the global priority peak).
    pub fn dep(&self) -> &[Option<u32>] {
        &self.dep
    }

    /// δ per point (∞ for the peak).
    pub fn delta(&self) -> &[f64] {
        &self.delta
    }

    pub fn stats(&self) -> StreamStats {
        self.stats
    }

    /// Current forest level sizes, largest first (the set bits of `len()`).
    pub fn level_sizes(&self) -> Vec<usize> {
        self.levels.iter().map(|lv| 1usize << lv.k).collect()
    }

    /// How many forest levels pin the *current* coordinate buffer by
    /// refcount (the rest pin older snapshots). Diagnostic for the
    /// no-defensive-copy contract: levels rebuilt by the latest merge
    /// always share the latest buffer.
    pub fn levels_sharing_current_buffer(&self) -> usize {
        self.levels.iter().filter(|lv| lv.tree.points().shares_storage(&self.pts)).count()
    }

    /// Absorb a batch of points, bringing ρ and the (λ, δ) forest to the
    /// state a from-scratch build on the concatenated set would produce.
    /// Monotone models (cutoff, Gaussian) repair incrementally; the
    /// kNN-rank model recomputes over the merged forest (see the field doc
    /// on [`StreamingSession`]). An empty batch is a no-op; a batch of the
    /// wrong dimension or with non-finite coordinates is rejected
    /// (positions in [`DpcError`] are batch-local) and leaves the session
    /// untouched.
    pub fn ingest(&mut self, batch: &PointStore<S>) -> Result<(), DpcError> {
        if batch.dim() != self.pts.dim() {
            return Err(DpcError::DimensionMismatch { expected: self.pts.dim(), got: batch.dim() });
        }
        if batch.is_empty() {
            return Ok(());
        }
        batch.validate_finite()?;
        let old_n = self.pts.len();
        let b = batch.len();
        let total = old_n + b;

        // The grown coordinate buffer: one allocation, filled in place
        // (`from_flat_fn` writes straight into the shared `Arc`, so growth
        // costs exactly one pass over old + batch coordinates). Existing
        // levels keep refcount pins on their own snapshots, so this never
        // invalidates a preserved tree.
        let (old_c, bat_c) = (self.pts.coords(), batch.coords());
        let split = old_c.len();
        let new_pts = PointStore::from_flat_fn(total, batch.dim(), |i| {
            if i < split {
                old_c[i]
            } else {
                bat_c[i - split]
            }
        });
        let new_ids: Vec<u32> = (old_n as u32..total as u32).collect();

        match self.model {
            DensityModel::KnnRadius { k } => {
                // Merge first: the recompute wants the post-merge forest.
                self.merge_levels(&new_pts, new_ids);
                self.pts = new_pts;
                self.reingest_knn(k as usize, old_n);
            }
            DensityModel::CutoffCount | DensityModel::GaussianKernel | DensityModel::Epanechnikov => {
                self.repair_monotone(&new_pts, new_ids, old_n, b);
            }
        }
        self.stats.ingests += 1;
        self.stats.points_ingested += b as u64;
        Ok(())
    }

    /// Incremental repair for pairwise-additive monotone models: each new
    /// pair contributes a fixed non-negative integer (1 for cutoff, a
    /// fixed-point kernel weight for Gaussian/Epanechnikov) to both
    /// endpoints, so the
    /// batch's effect on ρ is exactly the sum of its pair contributions —
    /// and the λ/δ repair can race cached dependents against only the
    /// priority-raised set.
    fn repair_monotone(&mut self, new_pts: &PointStore<S>, new_ids: Vec<u32>, old_n: usize, b: usize) {
        let total = old_n + b;
        let r_sq: S = radius_sq(self.d_cut);
        let inv_d_cut_sq = 1.0 / (self.d_cut * self.d_cut);
        // Kernel models sum per-pair weights; the cutoff count keeps the
        // cheaper unweighted range count (its implicit weight is 1).
        let weighted = self.model != DensityModel::CutoffCount;
        let model = self.model;

        // ---- Step-1 repair (against the PRE-merge forest) ----
        let t_rho = Instant::now();
        let batch_tree = KdTree::build_from_ids(new_pts, new_ids.clone());
        let (new_rho, changed_old) = {
            let levels = &self.levels;
            let np = new_pts;
            let weight = |ds: S| pair_weight(model, ds.to_f64(), inv_d_cut_sq);
            // Each new point's ρ = its contribution sum over the old forest
            // plus the batch (self-inclusive via the batch tree). The
            // per-tree sums are commutative integer adds, so the partition
            // into levels cannot perturb the total.
            let new_rho: Vec<u32> = parlay::par_map_grained(b, crate::dpc::QUERY_GRAIN, |t| {
                let q = np.point(old_n + t);
                if weighted {
                    let mut s = batch_tree.range_weight_sum(q, r_sq, &weight, &mut NoStats);
                    for lv in levels {
                        s += lv.tree.range_weight_sum(q, r_sq, &weight, &mut NoStats);
                    }
                    saturate_rho(s)
                } else {
                    let mut c = batch_tree.range_count(q, r_sq, &mut NoStats);
                    for lv in levels {
                        c += lv.tree.range_count(q, r_sq, &mut NoStats);
                    }
                    c as u32
                }
            });
            // The reverse direction: old points inside a batch point's ball
            // gain exactly that pair's contribution. Relaxed atomic adds
            // commute, so the sums are exact and deterministic without
            // materializing every (batch, old) close pair at once.
            let bumped: Vec<AtomicU64> = (0..old_n).map(|_| AtomicU64::new(0)).collect();
            parlay::par_for_grained(b, crate::dpc::QUERY_GRAIN, |t| {
                let q = np.point(old_n + t);
                let mut hits = Vec::new();
                for lv in levels {
                    lv.tree.range_report(q, r_sq, &mut hits);
                }
                for &i in &hits {
                    let w = if weighted { weight(np.dist_sq(old_n + t, i as usize)) } else { 1 };
                    bumped[i as usize].fetch_add(w, AtomicOrdering::Relaxed);
                }
            });
            let mut changed_old: Vec<u32> = Vec::new();
            for (i, c) in bumped.iter().enumerate() {
                let add = c.load(AtomicOrdering::Relaxed);
                // Saturating accumulate: `min(·, u32::MAX)` chains compose,
                // so a repaired ρ equals the fresh saturated sum even when
                // either side clipped (in-ball weights are ≥ 1, so any hit
                // below the clip raises ρ — priorities stay monotone).
                let nv = ((self.rho[i] as u64) + add).min(u32::MAX as u64) as u32;
                if nv != self.rho[i] {
                    self.rho[i] = nv;
                    changed_old.push(i as u32);
                }
            }
            (new_rho, changed_old)
        };
        self.rho.extend_from_slice(&new_rho);
        self.stats.rho_bumped += changed_old.len() as u64;
        self.stats.rho_secs += t_rho.elapsed().as_secs_f64();

        // ---- Forest merge (binary counter over the new total) ----
        self.merge_levels(new_pts, new_ids);
        self.pts = new_pts.clone();

        // ---- Step-2 repair ----
        let t_dep = Instant::now();
        // Maintain γ in place: only raised priorities moved.
        for &i in &changed_old {
            self.gamma[i as usize] = priority_key(self.rho[i as usize], i);
        }
        for i in old_n..total {
            self.gamma.push(priority_key(self.rho[i], i as u32));
        }
        // Every point whose priority increased: the batch plus ρ-bumped old
        // points. Exactly the candidates an unchanged point can newly gain.
        let mut raised = changed_old;
        raised.extend(old_n as u32..total as u32);
        let raised_tree = KdTree::build_from_ids(&self.pts, raised);

        let results: Vec<(Option<u32>, bool)> = {
            let pts = &self.pts;
            let levels = &self.levels;
            let g = &self.gamma;
            let dep = &self.dep;
            parlay::par_map_grained(total, crate::dpc::QUERY_GRAIN, |i| {
                let q = pts.point(i);
                let gi = g[i];
                // A cached dependent that still outranks the point pins the
                // old candidate minimum; only the raised set can beat it.
                let seed = if i < old_n {
                    match dep[i] {
                        Some(j) if g[j as usize] > gi => Some((j, pts.dist_sq(i, j as usize))),
                        Some(_) => None,
                        // The old peak never had candidates to lose.
                        None => Some((u32::MAX, S::INFINITY)),
                    }
                } else {
                    None
                };
                match seed {
                    Some(mut best) => {
                        raised_tree.nn_filtered(q, |j| g[j as usize] > gi, &mut best, &mut NoStats);
                        (if best.0 == u32::MAX { None } else { Some(best.0) }, false)
                    }
                    None => {
                        let mut best = (u32::MAX, S::INFINITY);
                        for lv in levels {
                            lv.tree.nn_filtered(q, |j| g[j as usize] > gi, &mut best, &mut NoStats);
                        }
                        (if best.0 == u32::MAX { None } else { Some(best.0) }, true)
                    }
                }
            })
        };

        self.dep.resize(total, None);
        self.delta.resize(total, f64::INFINITY);
        for (i, &(nd, full)) in results.iter().enumerate() {
            if full {
                self.stats.dep_full_queries += 1;
            } else {
                self.stats.dep_seeded_races += 1;
            }
            if i >= old_n || nd != self.dep[i] {
                self.stats.dep_changed += 1;
                self.dep[i] = nd;
                // Same formula as `dep::dependent_distances`, so reused and
                // repaired entries are bitwise indistinguishable.
                self.delta[i] = match nd {
                    Some(j) => self.pts.dist_sq(i, j as usize).to_f64().sqrt(),
                    None => f64::INFINITY,
                };
            }
        }
        self.stats.dep_secs += t_dep.elapsed().as_secs_f64();
    }

    /// Full recompute for the non-monotone kNN-rank model, against the
    /// already-merged forest. Ranks are global — one shrunken k-NN radius
    /// can demote every point ranked between the mover's old and new
    /// position — so no cached (ρ, λ, δ) entry is trustworthy after an
    /// ingest. The forest still amortizes the *index* (logarithmic rebuild
    /// work); only the queries rerun, exactly as a fresh session would run
    /// them.
    fn reingest_knn(&mut self, k: usize, old_n: usize) {
        let total = self.pts.len();
        let t_rho = Instant::now();
        let dk: Vec<S> = {
            let pts = &self.pts;
            let levels = &self.levels;
            parlay::par_map_grained(total, crate::dpc::QUERY_GRAIN, |i| {
                // One bounded heap threaded through every level: selection
                // of the k global minima is partition-independent, so this
                // equals the single-tree k-NN distance bit for bit.
                let mut heap: Vec<(S, u32)> = Vec::with_capacity(k + 1);
                for lv in levels {
                    lv.tree.knn_fold(pts.point(i), k, i as u32, &mut heap);
                }
                if heap.len() < k {
                    S::INFINITY
                } else {
                    heap[0].0
                }
            })
        };
        let new_rho = knn_rank_densities(&dk);
        let moved = (0..old_n).filter(|&i| new_rho[i] != self.rho[i]).count();
        self.stats.rho_bumped += moved as u64;
        self.rho = new_rho;
        self.stats.rho_secs += t_rho.elapsed().as_secs_f64();

        let t_dep = Instant::now();
        self.gamma = self.rho.iter().enumerate().map(|(i, &r)| priority_key(r, i as u32)).collect();
        let results: Vec<Option<u32>> = {
            let pts = &self.pts;
            let levels = &self.levels;
            let g = &self.gamma;
            parlay::par_map_grained(total, crate::dpc::QUERY_GRAIN, |i| {
                let q = pts.point(i);
                let gi = g[i];
                let mut best = (u32::MAX, S::INFINITY);
                for lv in levels {
                    lv.tree.nn_filtered(q, |j| g[j as usize] > gi, &mut best, &mut NoStats);
                }
                if best.0 == u32::MAX {
                    None
                } else {
                    Some(best.0)
                }
            })
        };
        self.stats.dep_full_queries += total as u64;
        self.dep.resize(total, None);
        self.delta.resize(total, f64::INFINITY);
        for (i, &nd) in results.iter().enumerate() {
            if i >= old_n || nd != self.dep[i] {
                self.stats.dep_changed += 1;
                self.dep[i] = nd;
                // Same formula as `dep::dependent_distances`.
                self.delta[i] = match nd {
                    Some(j) => self.pts.dist_sq(i, j as usize).to_f64().sqrt(),
                    None => f64::INFINITY,
                };
            }
        }
        self.stats.dep_secs += t_dep.elapsed().as_secs_f64();
    }

    /// Rebuild the forest for the grown total: levels whose power-of-two
    /// size still matches a set bit survive untouched; everything else
    /// (dropped levels + the batch) pools into freshly built trees for the
    /// gained bits.
    fn merge_levels(&mut self, new_pts: &PointStore<S>, new_ids: Vec<u32>) {
        let total = new_pts.len();
        let mut pool: Vec<u32> = Vec::new();
        let mut kept: Vec<OwnedLevel<S>> = Vec::with_capacity(self.levels.len() + 1);
        // Old levels are stored largest-first, which keeps the pool order
        // (and thus the rebuilt trees) deterministic.
        for lv in self.levels.drain(..) {
            if total & (1usize << lv.k) != 0 {
                kept.push(lv);
            } else {
                pool.extend_from_slice(&lv.ids);
            }
        }
        pool.extend(new_ids);
        let covered = kept.iter().fold(0usize, |m, lv| m | (1usize << lv.k));
        for k in (0..usize::BITS).rev() {
            let size = 1usize << k;
            if total & size != 0 && covered & size == 0 {
                let ids: Vec<u32> = pool.drain(..size).collect();
                self.stats.trees_built += 1;
                self.stats.tree_points_built += size as u64;
                kept.push(OwnedLevel::build(new_pts, k, ids));
            }
        }
        debug_assert!(pool.is_empty(), "merge pool must be fully consumed");
        kept.sort_by_key(|lv| std::cmp::Reverse(lv.k));
        self.levels = kept;
    }

    /// Step 3 against the maintained artifacts: identical to
    /// [`super::ClusterSession::cut`] on the concatenated point set. The
    /// density/dep timing slots report the cumulative repair cost the
    /// session has amortized (Table-3-style accounting stays truthful).
    pub fn cut(&self, rho_min: f64, delta_min: f64) -> Result<DpcResult, DpcError> {
        if self.pts.is_empty() {
            return Err(DpcError::EmptyInput);
        }
        session::validate_thresholds(rho_min, delta_min)?;
        let params =
            DpcParams { d_cut: self.d_cut, rho_min, delta_min, dtype: S::DTYPE, density: self.model };
        let mut out = session::cut_cached(&self.pts, &self.rho, &self.dep, &self.delta, params);
        out.timings.density_s = self.stats.rho_secs;
        out.timings.dep_s = self.stats.dep_secs;
        Ok(out)
    }

    /// Snapshot everything a checkpoint needs to reconstruct this session
    /// bit for bit: the concatenated store (a refcount bump), the artifact
    /// arrays, and the forest's **level partition**. The partition is state,
    /// not an implementation detail — which ids pool into which rebuilt
    /// tree on a future merge depends on it, so restoring a different
    /// partition would diverge from the uninterrupted session on later
    /// ingests (results would still be exact; the byte-identity contract
    /// with the pre-crash process would not).
    pub fn export_state(&self) -> StreamState<S> {
        StreamState {
            d_cut: self.d_cut,
            model: self.model,
            pts: self.pts.clone(),
            levels: self.levels.iter().map(|lv| (lv.k, lv.ids.clone())).collect(),
            rho: self.rho.clone(),
            dep: self.dep.clone(),
            delta: self.delta.clone(),
            stats: self.stats,
        }
    }

    /// Rebuild a session from an exported state. Level kd-trees are rebuilt
    /// from their id lists against the restored store — stores only grow
    /// and never mutate, so the coordinates at those ids are exactly the
    /// ones each level was originally built over, and `build_from_ids` is
    /// deterministic: the rebuilt trees equal the checkpointed ones.
    ///
    /// Validates the structural invariants (array lengths, the level
    /// partition, id ranges) and rejects violations with a typed error —
    /// a checkpoint decoder maps that to `DpcError::CorruptCheckpoint`,
    /// never a partially-restored session.
    pub fn from_state(state: StreamState<S>) -> Result<Self, DpcError> {
        let StreamState { d_cut, model, pts, mut levels, rho, dep, delta, stats } = state;
        if pts.dim() == 0 {
            return Err(DpcError::InvalidParam { name: "dim", value: 0.0, requirement: "must be positive" });
        }
        session::validate_d_cut(d_cut)?;
        model.validate()?;
        pts.validate_finite()?;
        let n = pts.len();
        let bad = |requirement: &'static str| DpcError::InvalidParam {
            name: "stream_state",
            value: n as f64,
            requirement,
        };
        if rho.len() != n || dep.len() != n || delta.len() != n {
            return Err(bad("rho/dep/delta must have one entry per point"));
        }
        if dep.iter().flatten().any(|&j| j as usize >= n) {
            return Err(bad("dependent ids must be in range"));
        }
        // The levels must partition 0..n into blocks of 2^k matching the
        // set bits of n (each id exactly once).
        let mut seen = vec![false; n];
        for (k, ids) in &levels {
            if *k >= usize::BITS || ids.len() != 1usize << k {
                return Err(bad("level size must be 2^k"));
            }
            for &id in ids {
                if id as usize >= n || std::mem::replace(&mut seen[id as usize], true) {
                    return Err(bad("levels must partition the ids"));
                }
            }
        }
        if !seen.iter().all(|&s| s) {
            return Err(bad("levels must cover every point"));
        }
        let mut ks: Vec<u32> = levels.iter().map(|&(k, _)| k).collect();
        ks.sort_unstable();
        ks.dedup();
        if ks.len() != levels.len() {
            return Err(bad("level sizes must be distinct powers of two"));
        }
        // Normalize to the invariant order (largest first) — `merge_levels`
        // keeps it, so an export is already sorted, but the decoder must
        // not trust that.
        levels.sort_by_key(|&(k, _)| std::cmp::Reverse(k));
        let gamma = rho.iter().enumerate().map(|(i, &r)| priority_key(r, i as u32)).collect();
        let owned = levels.into_iter().map(|(k, ids)| OwnedLevel::build(&pts, k, ids)).collect();
        Ok(StreamingSession { d_cut, model, pts, levels: owned, rho, gamma, dep, delta, stats })
    }
}

/// An exported [`StreamingSession`] — the serialization boundary between
/// the session and `crate::durability`'s checkpoint codec. Plain data:
/// no trees (rebuilt on restore), no γ (derived from ρ).
#[derive(Clone, Debug)]
pub struct StreamState<S: Scalar> {
    pub d_cut: f64,
    pub model: DensityModel,
    pub pts: PointStore<S>,
    /// `(k, ids)` per forest level, ids in each level's build order.
    pub levels: Vec<(u32, Vec<u32>)>,
    pub rho: Vec<u32>,
    pub dep: Vec<Option<u32>>,
    pub delta: Vec<f64>,
    /// Carried across restores so the observable repair accounting keeps
    /// the whole stream's history. Replay re-measures wall-clock for the
    /// replayed suffix, so timing fields are *not* part of the
    /// byte-identity contract (the integer counters are).
    pub stats: StreamStats,
}

impl<S: Scalar> StreamState<S> {
    /// Gather one level's coordinate rows in id order — the payload the
    /// checkpoint codec content-addresses per level. Because level
    /// buffers are immutable (a merge replaces levels, it never edits
    /// one) and the gather order is the ids' own order, an unchanged
    /// level yields byte-identical output on every export, which is what
    /// makes the `(crc64, len)` blob key a stable identity across
    /// checkpoints.
    pub fn level_coords(&self, ids: &[u32]) -> Vec<S> {
        let dim = self.pts.dim();
        let coords = self.pts.coords();
        let mut out = Vec::with_capacity(ids.len() * dim);
        for &id in ids {
            let base = id as usize * dim;
            out.extend_from_slice(&coords[base..base + dim]);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpc::{ClusterSession, DepAlgo};
    use crate::geom::PointSet;
    use crate::proputil::{gen_clustered_points, gen_degenerate_points, gen_uniform_points};
    use crate::prng::SplitMix64;

    fn prefix(pts: &PointSet, n: usize) -> PointSet {
        PointSet::new(pts.coords()[..n * pts.dim()].to_vec(), pts.dim())
    }

    /// After every batch the streaming artifacts must equal a fresh staged
    /// session on the same prefix.
    fn check_stream_matches_fresh(pts: &PointSet, d_cut: f64, batch_sizes: &[usize]) {
        let mut s = StreamingSession::<f64>::new(pts.dim(), d_cut).unwrap();
        let mut sent = 0usize;
        for &bsz in batch_sizes {
            let hi = (sent + bsz).min(pts.len());
            if hi == sent {
                break;
            }
            let batch = PointSet::new(pts.coords()[sent * pts.dim()..hi * pts.dim()].to_vec(), pts.dim());
            s.ingest(&batch).unwrap();
            sent = hi;
            let pre = prefix(pts, hi);
            let mut fresh = ClusterSession::build(&pre).unwrap();
            let rho = fresh.density(d_cut).unwrap();
            assert_eq!(s.rho(), &rho[..], "rho after {hi} points");
            let art = fresh.dependents(DepAlgo::Priority).unwrap();
            assert_eq!(s.dep(), &art.dep[..], "dep after {hi} points");
            assert_eq!(s.delta(), &art.delta[..], "delta after {hi} points");
            let a = s.cut(2.0, 4.0).unwrap();
            let b = fresh.cut(2.0, 4.0).unwrap();
            assert_eq!(a.labels, b.labels, "labels after {hi} points");
            assert_eq!(a.centers, b.centers, "centers after {hi} points");
        }
        assert_eq!(sent, pts.len(), "test must consume every point");
    }

    #[test]
    fn stream_matches_fresh_uniform() {
        let mut rng = SplitMix64::new(301);
        let pts = gen_uniform_points(&mut rng, 230, 2, 40.0);
        check_stream_matches_fresh(&pts, 4.0, &[64, 1, 7, 100, 58]);
    }

    #[test]
    fn stream_matches_fresh_clustered_3d() {
        let mut rng = SplitMix64::new(302);
        let pts = gen_clustered_points(&mut rng, 180, 3, 3, 60.0, 2.0);
        check_stream_matches_fresh(&pts, 3.0, &[1, 1, 1, 30, 147]);
    }

    #[test]
    fn stream_matches_fresh_degenerate_ties() {
        let mut rng = SplitMix64::new(303);
        let pts = gen_degenerate_points(&mut rng, 150, 2);
        check_stream_matches_fresh(&pts, 2.0, &[10, 50, 90]);
    }

    /// Stream-vs-fresh parity under every density model: the repair path
    /// (cutoff, Gaussian) and the recompute path (kNN) must both land on
    /// the fresh session's bytes after every batch.
    fn check_stream_matches_fresh_model(pts: &PointSet, d_cut: f64, model: DensityModel, batches: &[usize]) {
        let mut s = StreamingSession::<f64>::new_with_model(pts.dim(), d_cut, model).unwrap();
        assert_eq!(s.density_model(), model);
        let mut sent = 0usize;
        for &bsz in batches {
            let hi = (sent + bsz).min(pts.len());
            if hi == sent {
                break;
            }
            let batch = PointSet::new(pts.coords()[sent * pts.dim()..hi * pts.dim()].to_vec(), pts.dim());
            s.ingest(&batch).unwrap();
            sent = hi;
            let mut fresh = ClusterSession::build(&prefix(pts, hi)).unwrap().with_density_model(model);
            let rho = fresh.density(d_cut).unwrap();
            assert_eq!(s.rho(), &rho[..], "{model}: rho after {hi} points");
            let art = fresh.dependents(DepAlgo::Priority).unwrap();
            assert_eq!(s.dep(), &art.dep[..], "{model}: dep after {hi} points");
            assert_eq!(s.delta(), &art.delta[..], "{model}: delta after {hi} points");
        }
        assert_eq!(sent, pts.len());
    }

    #[test]
    fn stream_matches_fresh_gaussian_kernel() {
        let mut rng = SplitMix64::new(311);
        let pts = gen_clustered_points(&mut rng, 170, 2, 3, 50.0, 2.0);
        check_stream_matches_fresh_model(&pts, 3.0, DensityModel::GaussianKernel, &[40, 1, 70, 59]);
    }

    #[test]
    fn stream_matches_fresh_knn_rank() {
        let mut rng = SplitMix64::new(312);
        let pts = gen_uniform_points(&mut rng, 150, 2, 30.0);
        check_stream_matches_fresh_model(&pts, 3.0, DensityModel::KnnRadius { k: 3 }, &[33, 2, 80, 35]);
    }

    #[test]
    fn stream_matches_fresh_models_on_degenerate_ties() {
        let mut rng = SplitMix64::new(313);
        let pts = gen_degenerate_points(&mut rng, 120, 2);
        for model in DensityModel::REPRESENTATIVE {
            check_stream_matches_fresh_model(&pts, 2.0, model, &[30, 50, 40]);
        }
    }

    #[test]
    fn knn_stream_counts_full_queries_not_races() {
        let mut rng = SplitMix64::new(314);
        let pts = gen_uniform_points(&mut rng, 96, 2, 20.0);
        let mut s = StreamingSession::<f64>::new_with_model(2, 3.0, DensityModel::KnnRadius { k: 2 }).unwrap();
        s.ingest(&prefix(&pts, 64)).unwrap();
        s.ingest(&PointSet::new(pts.coords()[64 * 2..96 * 2].to_vec(), 2)).unwrap();
        let st = s.stats();
        assert_eq!(st.dep_seeded_races, 0, "knn never trusts a cached dependent");
        assert_eq!(st.dep_full_queries, 64 + 96);
    }

    #[test]
    fn new_with_model_validates_k() {
        assert!(matches!(
            StreamingSession::<f64>::new_with_model(2, 1.0, DensityModel::KnnRadius { k: 0 }),
            Err(DpcError::InvalidParam { name: "k", .. })
        ));
    }

    #[test]
    fn forest_levels_follow_binary_representation() {
        let mut rng = SplitMix64::new(304);
        let pts = gen_uniform_points(&mut rng, 100, 2, 30.0);
        let mut s = StreamingSession::<f64>::new(2, 3.0).unwrap();
        let mut sent = 0;
        for bsz in [5usize, 3, 8, 16, 1, 67] {
            let batch = PointSet::new(pts.coords()[sent * 2..(sent + bsz) * 2].to_vec(), 2);
            s.ingest(&batch).unwrap();
            sent += bsz;
            let sizes = s.level_sizes();
            assert_eq!(sizes.iter().sum::<usize>(), sent);
            for w in sizes.windows(2) {
                assert!(w[0] > w[1], "strictly descending powers: {sizes:?}");
            }
            assert!(sizes.iter().all(|z| z.is_power_of_two()));
        }
    }

    #[test]
    fn single_point_ingests_do_logarithmic_rebuild_work() {
        let mut rng = SplitMix64::new(305);
        let n = 256usize;
        let pts = gen_uniform_points(&mut rng, n, 2, 50.0);
        let mut s = StreamingSession::<f64>::new(2, 4.0).unwrap();
        for i in 0..n {
            let batch = PointSet::new(pts.point(i).to_vec(), 2);
            s.ingest(&batch).unwrap();
        }
        let st = s.stats();
        assert_eq!(st.ingests, n as u64);
        // Binary-counter amortization: Σ rebuild sizes ≤ n (log2 n + 1),
        // far below the Θ(n²) of rebuild-per-ingest.
        let bound = (n * (n.ilog2() as usize + 1)) as u64;
        assert!(st.tree_points_built <= bound, "{} > {bound}", st.tree_points_built);
    }

    #[test]
    fn rebuilt_levels_pin_the_current_buffer_by_refcount() {
        let mut rng = SplitMix64::new(306);
        let pts = gen_uniform_points(&mut rng, 64, 2, 30.0);
        let mut s = StreamingSession::<f64>::new(2, 3.0).unwrap();
        // First ingest: every level was just built against the new buffer.
        s.ingest(&prefix(&pts, 48)).unwrap();
        assert_eq!(s.level_sizes(), vec![32, 16]);
        assert_eq!(s.levels_sharing_current_buffer(), 2);
        // 48 = 0b110000; +1 gains only the 1-bit — the 32- and 16-levels
        // survive on their older (still refcount-pinned) snapshot, the new
        // 1-level shares the grown buffer.
        let one = PointSet::new(pts.coords()[48 * 2..49 * 2].to_vec(), 2);
        s.ingest(&one).unwrap();
        assert_eq!(s.level_sizes(), vec![32, 16, 1]);
        assert_eq!(s.levels_sharing_current_buffer(), 1);
    }

    #[test]
    fn ingest_validates_input_and_leaves_state_intact() {
        let mut s = StreamingSession::<f64>::new(2, 1.0).unwrap();
        s.ingest(&PointSet::new(vec![0.0, 0.0, 5.0, 5.0], 2)).unwrap();
        // Wrong dimension.
        assert!(matches!(
            s.ingest(&PointSet::new(vec![1.0, 2.0, 3.0], 3)),
            Err(DpcError::DimensionMismatch { expected: 2, got: 3 })
        ));
        // Non-finite (position is batch-local). Built via the unvalidated
        // generator path — `PointSet::new` itself rejects the NaN now.
        let poisoned = [0.0, f64::NAN];
        assert!(matches!(
            s.ingest(&PointSet::from_flat_fn(1, 2, |i| poisoned[i])),
            Err(DpcError::NonFiniteCoordinate { point: 0, dim: 1 })
        ));
        // Empty batch is a no-op.
        s.ingest(&PointSet::empty(2)).unwrap();
        assert_eq!(s.len(), 2);
        assert_eq!(s.rho(), &[1, 1]);
    }

    #[test]
    fn session_construction_rejects_bad_params() {
        assert!(matches!(StreamingSession::<f64>::new(0, 1.0), Err(DpcError::InvalidParam { name: "dim", .. })));
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            assert!(matches!(
                StreamingSession::<f64>::new(2, bad),
                Err(DpcError::InvalidParam { name: "d_cut", .. })
            ));
        }
    }

    #[test]
    fn cut_on_empty_stream_is_typed_error() {
        let s = StreamingSession::<f64>::new(2, 1.0).unwrap();
        assert!(matches!(s.cut(0.0, 1.0), Err(DpcError::EmptyInput)));
    }

    #[test]
    fn stream_matches_fresh_epanechnikov_kernel() {
        let mut rng = SplitMix64::new(315);
        let pts = gen_clustered_points(&mut rng, 160, 2, 3, 50.0, 2.0);
        check_stream_matches_fresh_model(&pts, 3.0, DensityModel::Epanechnikov, &[37, 1, 80, 42]);
    }

    /// The checkpoint/restore contract: a restored session continues
    /// exactly where the exported one left off — same artifacts now, same
    /// artifacts (and level partition) after further ingests.
    #[test]
    fn export_restore_round_trip_continues_identically() {
        let mut rng = SplitMix64::new(316);
        let pts = gen_uniform_points(&mut rng, 200, 2, 40.0);
        for model in DensityModel::REPRESENTATIVE {
            let mut a = StreamingSession::<f64>::new_with_model(2, 4.0, model).unwrap();
            a.ingest(&prefix(&pts, 130)).unwrap();
            let mut b = StreamingSession::from_state(a.export_state()).unwrap();
            assert_eq!(a.rho(), b.rho(), "{model}: restored rho");
            assert_eq!(a.dep(), b.dep(), "{model}: restored dep");
            assert_eq!(a.delta(), b.delta(), "{model}: restored delta");
            assert_eq!(a.level_sizes(), b.level_sizes(), "{model}: restored levels");
            let tail = PointSet::new(pts.coords()[130 * 2..200 * 2].to_vec(), 2);
            a.ingest(&tail).unwrap();
            b.ingest(&tail).unwrap();
            assert_eq!(a.rho(), b.rho(), "{model}: post-ingest rho");
            assert_eq!(a.dep(), b.dep(), "{model}: post-ingest dep");
            assert_eq!(a.delta(), b.delta(), "{model}: post-ingest delta");
            assert_eq!(a.level_sizes(), b.level_sizes(), "{model}: post-ingest levels");
            assert_eq!(a.stats().tree_points_built, b.stats().tree_points_built, "{model}: counters carry");
        }
    }

    #[test]
    fn from_state_rejects_structural_corruption() {
        let mut rng = SplitMix64::new(317);
        let pts = gen_uniform_points(&mut rng, 48, 2, 20.0);
        let mut s = StreamingSession::<f64>::new(2, 3.0).unwrap();
        s.ingest(&pts).unwrap();
        let good = s.export_state();
        assert!(StreamingSession::from_state(good.clone()).is_ok());
        // Truncated artifact array.
        let mut st = good.clone();
        st.rho.pop();
        assert!(matches!(StreamingSession::from_state(st), Err(DpcError::InvalidParam { .. })));
        // Out-of-range dependent.
        let mut st = good.clone();
        st.dep[0] = Some(999);
        assert!(matches!(StreamingSession::from_state(st), Err(DpcError::InvalidParam { .. })));
        // A duplicated level id breaks the partition.
        let mut st = good.clone();
        st.levels[0].1[0] = st.levels[0].1[1];
        assert!(matches!(StreamingSession::from_state(st), Err(DpcError::InvalidParam { .. })));
        // A level of non-2^k size.
        let mut st = good;
        st.levels[0].1.pop();
        assert!(matches!(StreamingSession::from_state(st), Err(DpcError::InvalidParam { .. })));
    }
}
