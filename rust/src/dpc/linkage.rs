//! Step 3 — single-linkage clustering via lock-free union-find
//! (§6.2, Algorithm 3).
//!
//! Every non-noise point that is *not* a cluster center (δ < δ_min) is
//! unioned with its dependent point, in parallel. Because each point has at
//! most one outgoing dependency edge and centers contribute none, each
//! resulting component is a tree containing exactly one center — the
//! component's cluster. Noise points (ρ < ρ_min) are left out of the forest
//! entirely and labeled −1.

use crate::dpc::{DpcParams, dep::dependent_distances};
use crate::geom::{PointStore, Scalar};
use crate::parlay;
use crate::unionfind::ConcurrentUnionFind;

#[derive(Debug)]
pub struct LinkageOutput {
    /// Cluster label per point: the *center's point id*, or −1 for noise.
    pub labels: Vec<i64>,
    pub centers: Vec<u32>,
    pub num_clusters: usize,
    pub num_noise: usize,
}

/// Algorithm 3 (with the noise handling of Definitions 4-5 made explicit).
pub fn single_linkage<S: Scalar>(pts: &PointStore<S>, rho: &[u32], dep: &[Option<u32>], params: DpcParams) -> LinkageOutput {
    let n = pts.len();
    let delta = dependent_distances(pts, dep);
    let is_noise: Vec<bool> = parlay::par_map(n, |i| (rho[i] as f64) < params.rho_min);
    // Center: non-noise with δ ≥ δ_min (the global peak has δ = ∞).
    let is_center: Vec<bool> = parlay::par_map(n, |i| !is_noise[i] && delta[i] >= params.delta_min);

    let uf = ConcurrentUnionFind::new(n);
    parlay::par_for(n, |i| {
        if !is_noise[i] && !is_center[i] {
            if let Some(j) = dep[i] {
                uf.union(i as u32, j);
            }
        }
    });

    // Each component contains exactly one center; label every member with
    // the center's id.
    let roots = uf.labels();
    let mut center_of_root: Vec<i64> = vec![-1; n];
    for i in 0..n {
        if is_center[i] {
            debug_assert_eq!(center_of_root[roots[i] as usize], -1, "two centers in one component");
            center_of_root[roots[i] as usize] = i as i64;
        }
    }
    let labels: Vec<i64> = parlay::par_map(n, |i| {
        if is_noise[i] {
            -1
        } else {
            center_of_root[roots[i] as usize]
        }
    });
    let centers: Vec<u32> = parlay::par_filter(n, |i| is_center[i], |i| i as u32);
    let num_noise = is_noise.iter().filter(|&&b| b).count();
    LinkageOutput { num_clusters: centers.len(), centers, labels, num_noise }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpc::{compute_density, dep::compute_dependents, DensityAlgo, DepAlgo};
    use crate::proputil::gen_clustered_points;
    use crate::prng::SplitMix64;

    #[test]
    fn every_non_noise_point_labeled_with_a_center() {
        let mut rng = SplitMix64::new(61);
        let pts = gen_clustered_points(&mut rng, 500, 2, 4, 200.0, 2.0);
        let params = DpcParams { d_cut: 4.0, rho_min: 2.0, delta_min: 30.0, ..DpcParams::default() };
        let rho = compute_density(&pts, params.d_cut, DensityAlgo::TreePruned);
        let dep = compute_dependents(&pts, &rho, params.rho_min, DepAlgo::Priority);
        let out = single_linkage(&pts, &rho, &dep, params);
        let centers: std::collections::HashSet<i64> = out.centers.iter().map(|&c| c as i64).collect();
        for i in 0..pts.len() {
            if out.labels[i] == -1 {
                assert!((rho[i] as f64) < params.rho_min);
            } else {
                assert!(centers.contains(&out.labels[i]), "point {i} labeled with non-center");
            }
        }
        // Every center is labeled with itself.
        for &c in &out.centers {
            assert_eq!(out.labels[c as usize], c as i64);
        }
    }

    #[test]
    fn delta_min_infinity_means_every_point_is_own_cluster_or_peakless() {
        // With δ_min = ∞ only the global peak(s) are centers.
        let mut rng = SplitMix64::new(62);
        let pts = gen_clustered_points(&mut rng, 200, 2, 2, 100.0, 2.0);
        let params = DpcParams { d_cut: 5.0, rho_min: 0.0, delta_min: f64::INFINITY, ..DpcParams::default() };
        let rho = compute_density(&pts, params.d_cut, DensityAlgo::TreePruned);
        let dep = compute_dependents(&pts, &rho, 0.0, DepAlgo::Priority);
        let out = single_linkage(&pts, &rho, &dep, params);
        assert_eq!(out.num_clusters, 1); // only the peak has δ = ∞
        assert_eq!(out.num_noise, 0);
        let l = out.labels[out.centers[0] as usize];
        assert!(out.labels.iter().all(|&x| x == l));
    }

    #[test]
    fn delta_min_zero_means_every_point_is_a_center() {
        let mut rng = SplitMix64::new(63);
        let pts = gen_clustered_points(&mut rng, 100, 2, 2, 50.0, 2.0);
        let params = DpcParams { d_cut: 5.0, rho_min: 0.0, delta_min: 0.0, ..DpcParams::default() };
        let rho = compute_density(&pts, params.d_cut, DensityAlgo::TreePruned);
        let dep = compute_dependents(&pts, &rho, 0.0, DepAlgo::Priority);
        let out = single_linkage(&pts, &rho, &dep, params);
        assert_eq!(out.num_clusters, 100);
        for (i, &l) in out.labels.iter().enumerate() {
            assert_eq!(l, i as i64);
        }
    }
}
