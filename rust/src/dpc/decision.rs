//! Decision graph support (Rodriguez & Laio [57]): the (ρ, δ) scatter used
//! to pick `ρ_min` / `δ_min` by eye. Cluster centers are the points with
//! anomalously large δ at non-trivial ρ; DPC's robustness to
//! hyper-parameters comes from this plot being easy to threshold.

use std::io::Write;

use crate::dpc::DpcResult;
use crate::error::DpcError;

/// One decision-graph point.
#[derive(Clone, Copy, Debug)]
pub struct DecisionPoint {
    pub id: u32,
    pub rho: u32,
    pub delta: f64,
}

/// Extract the decision graph, sorted by descending γ = ρ·δ (the usual
/// center-scoring heuristic). ∞-δ points sort first, *among themselves by
/// descending ρ* — a masked cut can hold many of them (every noise point
/// whose dependent was masked gets δ = ∞, alongside the global peak), and
/// ρ·∞ collapses them into one tie, so the ρ order is the only useful
/// signal there. All remaining ties break by ascending id, keeping the
/// ordering total and deterministic.
pub fn decision_graph(result: &DpcResult) -> Vec<DecisionPoint> {
    let mut pts: Vec<DecisionPoint> = (0..result.rho.len())
        .map(|i| DecisionPoint { id: i as u32, rho: result.rho[i], delta: result.delta[i] })
        .collect();
    pts.sort_by(|a, b| match (a.delta.is_infinite(), b.delta.is_infinite()) {
        (true, true) => b.rho.cmp(&a.rho).then(a.id.cmp(&b.id)),
        (true, false) => std::cmp::Ordering::Less,
        (false, true) => std::cmp::Ordering::Greater,
        (false, false) => {
            let (ka, kb) = (score(a), score(b));
            // lint: allow(panic-surface) — both deltas are finite in this
            // arm and rho is integral, so the scores are never NaN.
            kb.partial_cmp(&ka).unwrap().then(a.id.cmp(&b.id))
        }
    });
    pts
}

/// γ = ρ·δ. Callers handle ∞ δ before scoring (the comparator above and
/// [`finite`] below), so this is only ever evaluated on finite deltas.
fn score(p: &DecisionPoint) -> f64 {
    debug_assert!(p.delta.is_finite());
    p.rho as f64 * p.delta
}

/// Suggest (ρ_min, δ_min) for a target number of clusters `k`: pick the k-th
/// largest δ gap among the top candidates. `k` must be in `1..=graph.len()`.
pub fn suggest_params(graph: &[DecisionPoint], k: usize) -> Result<(f64, f64), DpcError> {
    if k < 1 || k > graph.len() {
        return Err(DpcError::InvalidParam {
            name: "k",
            value: k as f64,
            requirement: "must be between 1 and the number of points",
        });
    }
    // δ_min: halfway (log-scale) between the k-th and (k+1)-th candidate δ.
    let dk = finite(graph[k - 1].delta, graph);
    let dn = if k < graph.len() { finite(graph[k].delta, graph) } else { 0.0 };
    let delta_min = if dn > 0.0 { (dk * dn).sqrt() } else { dk * 0.5 };
    Ok((0.0, delta_min))
}

fn finite(d: f64, graph: &[DecisionPoint]) -> f64 {
    if d.is_finite() {
        d
    } else {
        // ∞ (the global peak): substitute the largest finite δ times 2.
        graph.iter().map(|p| p.delta).filter(|d| d.is_finite()).fold(0.0, f64::max) * 2.0
    }
}

/// Write the decision graph as CSV (`id,rho,delta`).
pub fn write_csv<W: Write>(graph: &[DecisionPoint], mut w: W) -> std::io::Result<()> {
    writeln!(w, "id,rho,delta")?;
    for p in graph {
        writeln!(w, "{},{},{}", p.id, p.rho, p.delta)?;
    }
    Ok(())
}

/// Render a coarse ASCII scatter of the decision graph (rows = δ buckets,
/// cols = ρ buckets) for terminal inspection.
pub fn ascii_plot(graph: &[DecisionPoint], width: usize, height: usize) -> String {
    let max_rho = graph.iter().map(|p| p.rho).max().unwrap_or(1).max(1) as f64;
    let max_delta = graph.iter().map(|p| finite(p.delta, graph)).fold(0.0, f64::max).max(1e-12);
    let mut cells = vec![vec![0u32; width]; height];
    for p in graph {
        let x = ((p.rho as f64 / max_rho) * (width - 1) as f64).round() as usize;
        let y = ((finite(p.delta, graph) / max_delta) * (height - 1) as f64).round() as usize;
        cells[height - 1 - y][x] += 1;
    }
    let mut out = String::new();
    out.push_str(&format!("delta (max {max_delta:.3})\n"));
    for row in &cells {
        out.push('|');
        for &c in row {
            out.push(match c {
                0 => ' ',
                1 => '.',
                2..=4 => 'o',
                5..=16 => 'O',
                _ => '@',
            });
        }
        out.push('\n');
    }
    out.push('+');
    out.push_str(&"-".repeat(width));
    out.push_str(&format!("> rho (max {max_rho})\n"));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpc::{Dpc, DpcParams};
    use crate::geom::PointSet;
    use crate::prng::SplitMix64;

    fn blobs() -> PointSet {
        let mut rng = SplitMix64::new(81);
        let mut coords = Vec::new();
        for c in [(0.0, 0.0), (50.0, 0.0), (0.0, 50.0)] {
            for _ in 0..100 {
                coords.push(c.0 + rng.normal());
                coords.push(c.1 + rng.normal());
            }
        }
        PointSet::new(coords, 2)
    }

    #[test]
    fn top_decision_points_are_the_blob_centers() {
        let pts = blobs();
        let out = Dpc::new(DpcParams { d_cut: 3.0, rho_min: 0.0, delta_min: 10.0, ..DpcParams::default() }).run(&pts).unwrap();
        let graph = decision_graph(&out);
        // Top 3 by ρ·δ should each come from a different blob.
        let blob_of = |id: u32| (id / 100) as usize;
        let blobs: std::collections::HashSet<usize> = graph[..3].iter().map(|p| blob_of(p.id)).collect();
        assert_eq!(blobs.len(), 3, "top-3: {:?}", &graph[..3]);
        // And there's a big δ gap after rank 3.
        assert!(finite(graph[2].delta, &graph) > 5.0 * graph[3].delta);
    }

    #[test]
    fn suggested_delta_separates_k_clusters() {
        let pts = blobs();
        let params0 = DpcParams { d_cut: 3.0, rho_min: 0.0, delta_min: 1.0, ..DpcParams::default() };
        let out = Dpc::new(params0).run(&pts).unwrap();
        let graph = decision_graph(&out);
        let (rho_min, delta_min) = suggest_params(&graph, 3).unwrap();
        let out2 = Dpc::new(DpcParams { d_cut: 3.0, rho_min, delta_min, ..DpcParams::default() }).run(&pts).unwrap();
        assert_eq!(out2.num_clusters, 3);
    }

    /// Hand-built result (no pipeline): the γ-ordering is fully specified —
    /// ∞ δ first (by ρ desc, then id), then ρ·δ desc, then id.
    fn synthetic_result(rho: Vec<u32>, delta: Vec<f64>) -> crate::dpc::DpcResult {
        let n = rho.len();
        crate::dpc::DpcResult {
            rho,
            delta,
            dep: vec![None; n],
            labels: vec![0; n],
            centers: vec![],
            num_clusters: 0,
            num_noise: 0,
            timings: Default::default(),
        }
    }

    #[test]
    fn gamma_ordering_is_exactly_specified() {
        let out = synthetic_result(
            //        id: 0     1    2    3     4    5
            vec![5, 2, 9, 4, 4, 7],
            vec![2.0, f64::INFINITY, 1.0, 3.0, 3.0, f64::INFINITY],
        );
        let graph = decision_graph(&out);
        let ids: Vec<u32> = graph.iter().map(|p| p.id).collect();
        // ∞ δ first, by ρ desc: id5 (ρ=7) then id1 (ρ=2). Finite by ρ·δ:
        // id3/id4 tie at 12 (id asc), id0 at 10, id2 at 9.
        assert_eq!(ids, vec![5, 1, 3, 4, 0, 2]);
    }

    #[test]
    fn equal_scores_break_by_ascending_id() {
        let out = synthetic_result(vec![4, 2, 4], vec![3.0, 6.0, 3.0]);
        let ids: Vec<u32> = decision_graph(&out).iter().map(|p| p.id).collect();
        assert_eq!(ids, vec![0, 1, 2]); // all score 12, id order
    }

    #[test]
    fn all_infinite_deltas_order_by_rho() {
        // Degenerate single-cluster-per-point cut: every δ is ∞.
        let out = synthetic_result(vec![1, 9, 5], vec![f64::INFINITY; 3]);
        let ids: Vec<u32> = decision_graph(&out).iter().map(|p| p.id).collect();
        assert_eq!(ids, vec![1, 2, 0]);
        // suggest_params still works: the ∞ substitute is 2× the largest
        // finite δ, which here (none finite) is 0 ⇒ δ_min = 0.
        let (rho_min, delta_min) = suggest_params(&decision_graph(&out), 1).unwrap();
        assert_eq!(rho_min, 0.0);
        assert_eq!(delta_min, 0.0);
    }

    #[test]
    fn single_point_graph_suggestion() {
        let out = synthetic_result(vec![1], vec![f64::INFINITY]);
        let graph = decision_graph(&out);
        assert_eq!(graph.len(), 1);
        assert!(suggest_params(&graph, 1).is_ok());
        assert!(suggest_params(&graph, 2).is_err());
    }

    #[test]
    fn suggest_params_rejects_out_of_range_k() {
        let pts = blobs();
        let out = Dpc::new(DpcParams { d_cut: 3.0, rho_min: 0.0, delta_min: 10.0, ..DpcParams::default() }).run(&pts).unwrap();
        let graph = decision_graph(&out);
        assert!(matches!(suggest_params(&graph, 0), Err(DpcError::InvalidParam { name: "k", .. })));
        assert!(matches!(suggest_params(&graph, graph.len() + 1), Err(DpcError::InvalidParam { name: "k", .. })));
    }

    #[test]
    fn csv_roundtrip_shape() {
        let pts = blobs();
        let out = Dpc::new(DpcParams { d_cut: 3.0, rho_min: 0.0, delta_min: 10.0, ..DpcParams::default() }).run(&pts).unwrap();
        let graph = decision_graph(&out);
        let mut buf = Vec::new();
        write_csv(&graph, &mut buf).unwrap();
        let s = String::from_utf8(buf).unwrap();
        assert_eq!(s.lines().count(), pts.len() + 1);
        assert!(s.starts_with("id,rho,delta"));
    }

    #[test]
    fn ascii_plot_is_well_formed() {
        let pts = blobs();
        let out = Dpc::new(DpcParams { d_cut: 3.0, rho_min: 0.0, delta_min: 10.0, ..DpcParams::default() }).run(&pts).unwrap();
        let graph = decision_graph(&out);
        let plot = ascii_plot(&graph, 40, 10);
        assert_eq!(plot.lines().count(), 12); // header + 10 rows + axis
    }
}
