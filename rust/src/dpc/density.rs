//! Pluggable **exact density models** for Step 1.
//!
//! The paper fixes ρ to the count-within-`d_cut` model, but everything
//! downstream of Step 1 — the priority key, all five dependent-point
//! algorithms, the linkage cut, the streaming repair — only consumes an
//! integer ρ per point. [`DensityModel`] exploits that seam: three exact
//! density definitions share one pipeline.
//!
//! - [`DensityModel::CutoffCount`] — ρ(x) = |{y : D(x,y) ≤ d_cut}|, the
//!   paper's model and the default. Bit-for-bit identical to the pre-model
//!   pipeline (it *is* the pre-model pipeline).
//! - [`DensityModel::KnnRadius`] — ρ(x) = the competition rank of x's
//!   k-th-nearest-neighbor distance: `#{y : d_k(y) > d_k(x)}` (PECANN-style
//!   kNN density). Smaller k-NN radius ⇒ denser ⇒ larger rank. The rank is
//!   a *rank-invertible* image of d_k — it preserves exactly the order
//!   information the priority key consumes — so ρ stays a small integer and
//!   tie-breaks remain the lexicographic id rule.
//! - [`DensityModel::GaussianKernel`] — ρ(x) = Σ_{D(x,y) ≤ d_cut}
//!   round(2¹² · exp(−D(x,y)²/d_cut²)), a truncated Gaussian kernel density
//!   accumulated in **fixed point**. Integer addition commutes and
//!   associates, so the sum is independent of traversal order, of how the
//!   streaming forest partitions the points, and of thread count — the
//!   property the paper's exactness (and PR 4's precision-independent
//!   tie-break invariant) rests on. Floating-point accumulation would
//!   surrender all three.
//! - [`DensityModel::Epanechnikov`] — ρ(x) = Σ_{D(x,y) ≤ d_cut}
//!   round(2¹² · (1 − D(x,y)²/d_cut²)), the parabolic (Epanechnikov)
//!   kernel in the same fixed-point scheme. Unlike the Gaussian it needs
//!   no `exp`, so its weights are platform-exact arithmetic end to end.
//!   The *tophat* (uniform) kernel needs no variant of its own: a constant
//!   in-ball weight is the cutoff count up to scale, so `"tophat"` parses
//!   as an alias of [`DensityModel::CutoffCount`].
//!
//! ## Exactness per model
//!
//! *CutoffCount* and *GaussianKernel* are **pairwise-additive**: ρ(x) is a
//! commutative integer sum of per-pair contributions, so an inserted batch
//! changes old densities by exactly the contribution of the new pairs —
//! the streaming session repairs them incrementally and stays byte-exact.
//! They are also **monotone under insertion** (contributions are ≥ 1 inside
//! the ball), which the streaming λ/δ repair's seeded-race shortcut
//! requires. *KnnRadius* is neither — adding points can *shrink* another
//! point's d_k and thus demote third parties' ranks — so the streaming
//! session recomputes (ρ, λ, δ) over its forest per ingest instead of
//! repairing (exact, just not incremental; see `dpc::stream`).
//!
//! The Gaussian weights quantize `exp` evaluated in f64 on the exactly
//! widened squared distance. Within one platform that is fully
//! deterministic (the oracle and every engine share [`gaussian_weight`]);
//! across platforms `exp` may differ in the last ulp, which is why the
//! golden conformance snapshots pin the cutoff model only.
//!
//! Every tree-backed model here inherits the kd-tree's blocked leaves
//! (`kdtree::leaf`): each leaf a traversal reaches costs one
//! [`Scalar::dist_sq_block`] sweep — the SIMD kernel when available,
//! bit-identical to the scalar path either way — which is where the bulk
//! of Step 1's runtime goes.

use std::fmt;

use crate::error::DpcError;
use crate::geom::{radius_sq, PointStore, Scalar};
use crate::kdtree::{KdTree, NoStats};
use crate::parlay;

use super::{DensityAlgo, QUERY_GRAIN};

/// What Step 1 computes — the density *definition*. [`DensityAlgo`] remains
/// the orthogonal execution-strategy axis (its baseline/no-prune ablations
/// are specific to the cutoff model; the other models execute on the arena
/// kd-tree, or all-pairs under [`DensityAlgo::Naive`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum DensityModel {
    /// ρ(x) = #points within `d_cut` of x (self-inclusive) — the paper's
    /// model, today's behavior, unchanged.
    #[default]
    CutoffCount,
    /// ρ(x) = #{y : d_k(y) > d_k(x)} where d_k is the distance to the k-th
    /// nearest neighbor (excluding self; ∞ when fewer than k others exist).
    /// Equal d_k ⇒ equal ρ, so the id tie-break stays in charge of order.
    KnnRadius { k: u32 },
    /// ρ(x) = Σ over the `d_cut` ball of fixed-point Gaussian weights
    /// ([`gaussian_weight`]), saturating at `u32::MAX`.
    GaussianKernel,
    /// ρ(x) = Σ over the `d_cut` ball of fixed-point parabolic weights
    /// ([`epanechnikov_weight`]), saturating at `u32::MAX`. A boundary
    /// pair (D = d_cut exactly) contributes weight 0 — harmless for
    /// monotonicity (ρ never decreases) and for saturation (the min-chain
    /// still composes).
    Epanechnikov,
}

impl DensityModel {
    /// One representative of each model — what conformance/differential
    /// suites iterate (mirrors `DepAlgo::ALL`).
    pub const REPRESENTATIVE: [DensityModel; 4] = [
        DensityModel::CutoffCount,
        DensityModel::KnnRadius { k: 4 },
        DensityModel::GaussianKernel,
        DensityModel::Epanechnikov,
    ];

    /// Is ρ a commutative per-pair sum that can only grow when points are
    /// inserted? Decides whether the streaming session may repair (ρ, λ, δ)
    /// incrementally or must recompute them over its forest (both exact).
    pub fn monotone_under_insertion(&self) -> bool {
        !matches!(self, DensityModel::KnnRadius { .. })
    }

    /// Validate model-specific hyper-parameters (the `k` of `knn:<k>`).
    pub fn validate(&self) -> Result<(), DpcError> {
        if let DensityModel::KnnRadius { k: 0 } = self {
            return Err(DpcError::InvalidParam {
                name: "k",
                value: 0.0,
                requirement: "knn density needs k >= 1",
            });
        }
        Ok(())
    }
}

impl fmt::Display for DensityModel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DensityModel::CutoffCount => f.write_str("cutoff"),
            DensityModel::KnnRadius { k } => write!(f, "knn:{k}"),
            DensityModel::GaussianKernel => f.write_str("gauss"),
            DensityModel::Epanechnikov => f.write_str("epan"),
        }
    }
}

impl std::str::FromStr for DensityModel {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            // "tophat" is the uniform in-ball kernel — the cutoff count up
            // to a constant scale, so it shares the variant.
            "cutoff" | "cutoff-count" | "tophat" => Ok(DensityModel::CutoffCount),
            "gauss" | "gaussian" => Ok(DensityModel::GaussianKernel),
            "epan" | "epanechnikov" => Ok(DensityModel::Epanechnikov),
            other => match other.strip_prefix("knn:").map(str::parse::<u32>) {
                Some(Ok(k)) if k >= 1 => Ok(DensityModel::KnnRadius { k }),
                Some(_) => Err(format!("bad k in density model {other:?} (want knn:<k>, k >= 1)")),
                None => {
                    Err(format!("unknown density model {other:?} (cutoff | knn:<k> | gauss | epan)"))
                }
            },
        }
    }
}

/// Fixed-point scale of the Gaussian kernel: weights live in
/// `[round(e⁻¹·4096), 4096] = [1507, 4096]`, so every in-ball neighbor
/// contributes a *positive* integer (monotonicity) with ~3.6 decimal digits
/// of kernel resolution.
pub const GAUSS_SCALE: f64 = 4096.0;

/// The canonical quantized Gaussian weight of a pair at squared distance
/// `dist_sq` (already widened to f64 — exact for both scalar types), with
/// `inv_d_cut_sq = 1/d_cut²` computed in f64. Every implementation — tree
/// engines, naive scans, the O(n²) oracle, the streaming repair — must call
/// this one function: the model is *defined* by it.
#[inline]
pub fn gaussian_weight(dist_sq: f64, inv_d_cut_sq: f64) -> u64 {
    ((-dist_sq * inv_d_cut_sq).exp() * GAUSS_SCALE).round() as u64
}

/// The canonical quantized Epanechnikov (parabolic) weight of a pair at
/// squared distance `dist_sq`: round(4096 · (1 − dist_sq/d_cut²)), clamped
/// at 0. Weights live in `[0, 4096]` — zero exactly at the ball boundary.
/// Pure arithmetic (no transcendentals), so unlike [`gaussian_weight`] it
/// is bit-identical across platforms. Like the Gaussian, every
/// implementation must call this one function: the model is defined by it.
#[inline]
pub fn epanechnikov_weight(dist_sq: f64, inv_d_cut_sq: f64) -> u64 {
    ((1.0 - dist_sq * inv_d_cut_sq).max(0.0) * GAUSS_SCALE).round() as u64
}

/// The fixed-point pair weight of a pairwise-additive model: 1 for the
/// cutoff count, the kernel weight for Gaussian/Epanechnikov. The one
/// dispatch point the streaming repair and the weighted tree scans share
/// (kNN has no per-pair weights and must not reach here).
#[inline]
pub fn pair_weight(model: DensityModel, dist_sq: f64, inv_d_cut_sq: f64) -> u64 {
    match model {
        DensityModel::CutoffCount => 1,
        DensityModel::GaussianKernel => gaussian_weight(dist_sq, inv_d_cut_sq),
        DensityModel::Epanechnikov => epanechnikov_weight(dist_sq, inv_d_cut_sq),
        // lint: allow(panic-surface) — guarded by the dispatch in
        // compute_density, which never routes KnnRadius through this path.
        DensityModel::KnnRadius { .. } => unreachable!("knn density has no per-pair weight"),
    }
}

/// Saturate a fixed-point weight sum into the pipeline's `u32` ρ slot.
/// Saturation commutes with addition (`min(a+b, M)` chains associate), so
/// incremental repair of a saturated ρ still matches a fresh computation.
#[inline]
pub fn saturate_rho(sum: u64) -> u32 {
    sum.min(u32::MAX as u64) as u32
}

/// Step 1 under any model. For [`DensityModel::CutoffCount`] this is
/// byte-for-byte [`super::compute_density`]; the other models honor
/// [`DensityAlgo::Naive`] as the all-pairs reference and run every
/// tree-flavored algo on the arena kd-tree (the baseline/no-prune ablations
/// are cutoff-specific).
pub fn compute_density_model<S: Scalar>(
    pts: &PointStore<S>,
    d_cut: f64,
    model: DensityModel,
    algo: DensityAlgo,
) -> Vec<u32> {
    match model {
        DensityModel::CutoffCount => super::compute_density(pts, d_cut, algo),
        _ if algo == DensityAlgo::Naive => naive_model_density(pts, d_cut, model),
        _ => {
            let tree = KdTree::build(pts);
            tree_model_density(pts, &tree, d_cut, model)
        }
    }
}

/// Tree-backed kNN/Gaussian density over a caller-provided kd-tree (the
/// staged session passes its cached tree; [`compute_density_model`] builds a
/// throwaway). Must agree bit-for-bit with [`naive_model_density`].
pub(crate) fn tree_model_density<S: Scalar>(
    pts: &PointStore<S>,
    tree: &KdTree<S>,
    d_cut: f64,
    model: DensityModel,
) -> Vec<u32> {
    match model {
        DensityModel::CutoffCount => {
            // lint: allow(panic-surface) — the session dispatch sends
            // CutoffCount through compute_density before reaching here.
            unreachable!("cutoff density runs through compute_density / the session's pruned path")
        }
        DensityModel::KnnRadius { k } => {
            let dk: Vec<S> = parlay::par_map_grained(pts.len(), QUERY_GRAIN, |i| {
                tree.kth_nn_dist_sq(pts.point(i), k as usize, i as u32)
            });
            knn_rank_densities(&dk)
        }
        DensityModel::GaussianKernel | DensityModel::Epanechnikov => {
            let r_sq: S = radius_sq(d_cut);
            let inv = 1.0 / (d_cut * d_cut);
            let weight = |ds: S| pair_weight(model, ds.to_f64(), inv);
            parlay::par_map_grained(pts.len(), QUERY_GRAIN, |i| {
                saturate_rho(tree.range_weight_sum(pts.point(i), r_sq, &weight, &mut NoStats))
            })
        }
    }
}

/// All-pairs kNN/Gaussian density — the `DensityAlgo::Naive` leg and the
/// cross-check the conformance suite holds the tree path against.
fn naive_model_density<S: Scalar>(pts: &PointStore<S>, d_cut: f64, model: DensityModel) -> Vec<u32> {
    let n = pts.len();
    match model {
        // lint: allow(panic-surface) — same dispatch invariant as the tree
        // leg: CutoffCount never reaches the naive model path.
        DensityModel::CutoffCount => unreachable!("cutoff density runs through compute_density"),
        DensityModel::KnnRadius { k } => {
            let k = k as usize;
            let dk: Vec<S> = parlay::par_map_grained(n, QUERY_GRAIN, |i| {
                let q = pts.point(i);
                let mut ds: Vec<S> = (0..n).filter(|&j| j != i).map(|j| pts.dist_sq_to(j, q)).collect();
                if ds.len() < k {
                    return S::INFINITY;
                }
                // Only the k-th smallest *value* matters; ties among equal
                // distances cannot change it.
                // lint: allow(panic-surface) — distances are sums of squares
                // of ingest-validated finite coordinates, never NaN.
                ds.select_nth_unstable_by(k - 1, |a, b| a.partial_cmp(b).unwrap());
                ds[k - 1]
            });
            knn_rank_densities(&dk)
        }
        DensityModel::GaussianKernel | DensityModel::Epanechnikov => {
            let r_sq: S = radius_sq(d_cut);
            let inv = 1.0 / (d_cut * d_cut);
            parlay::par_map_grained(n, QUERY_GRAIN, |i| {
                let q = pts.point(i);
                let mut sum = 0u64;
                for j in 0..n {
                    let ds = pts.dist_sq_to(j, q);
                    if ds <= r_sq {
                        sum += pair_weight(model, ds.to_f64(), inv);
                    }
                }
                saturate_rho(sum)
            })
        }
    }
}

/// Competition ranks of k-NN distances, descending: ρ(x) = #{y : d_k(y) >
/// d_k(x)}. Ties share a rank (so the priority key's id rule — not the
/// partition of equal distances across a sort — decides their order), and
/// the densest point gets the largest ρ. Values are exact `S` comparisons;
/// ∞ entries (fewer than k neighbors) tie at rank 0.
pub(crate) fn knn_rank_densities<S: Scalar>(dk: &[S]) -> Vec<u32> {
    let n = dk.len();
    let mut order: Vec<u32> = (0..n as u32).collect();
    order.sort_by(|&a, &b| {
        // The unwrap cannot fire: every ingress (PointStore::try_new, file
        // readers, stream/coordinator ingest) rejects non-finite
        // coordinates, so each d_k is a sum of squares of finite values —
        // finite or +∞, never NaN, and partial_cmp is total over those.
        // lint: allow(panic-surface) — see the ingress argument above.
        dk[b as usize].partial_cmp(&dk[a as usize]).unwrap().then(a.cmp(&b))
    });
    let mut rho = vec![0u32; n];
    let mut rank = 0u32;
    for (pos, &i) in order.iter().enumerate() {
        if pos > 0 && dk[i as usize] != dk[order[pos - 1] as usize] {
            rank = pos as u32;
        }
        rho[i as usize] = rank;
    }
    rho
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proputil::{gen_degenerate_points, gen_uniform_points};
    use crate::prng::SplitMix64;

    #[test]
    fn parse_and_display_round_trip() {
        for (s, m) in [
            ("cutoff", DensityModel::CutoffCount),
            ("knn:3", DensityModel::KnnRadius { k: 3 }),
            ("gauss", DensityModel::GaussianKernel),
            ("epan", DensityModel::Epanechnikov),
        ] {
            assert_eq!(s.parse::<DensityModel>().unwrap(), m);
            assert_eq!(m.to_string().parse::<DensityModel>().unwrap(), m);
        }
        assert_eq!("cutoff-count".parse::<DensityModel>().unwrap(), DensityModel::CutoffCount);
        assert_eq!("gaussian".parse::<DensityModel>().unwrap(), DensityModel::GaussianKernel);
        assert_eq!("epanechnikov".parse::<DensityModel>().unwrap(), DensityModel::Epanechnikov);
        // The uniform kernel is the cutoff count up to scale — alias, not a
        // fourth weighting.
        assert_eq!("tophat".parse::<DensityModel>().unwrap(), DensityModel::CutoffCount);
        for bad in ["knn", "knn:", "knn:0", "knn:-1", "triangular"] {
            assert!(bad.parse::<DensityModel>().is_err(), "{bad}");
        }
    }

    #[test]
    fn validate_rejects_zero_k() {
        assert!(DensityModel::KnnRadius { k: 0 }.validate().is_err());
        assert!(DensityModel::KnnRadius { k: 1 }.validate().is_ok());
        assert!(DensityModel::CutoffCount.validate().is_ok());
        assert!(DensityModel::GaussianKernel.validate().is_ok());
        assert!(DensityModel::Epanechnikov.validate().is_ok());
    }

    #[test]
    fn monotonicity_classification() {
        assert!(DensityModel::CutoffCount.monotone_under_insertion());
        assert!(DensityModel::GaussianKernel.monotone_under_insertion());
        assert!(DensityModel::Epanechnikov.monotone_under_insertion());
        assert!(!DensityModel::KnnRadius { k: 2 }.monotone_under_insertion());
    }

    #[test]
    fn gaussian_weight_bounds_and_monotonicity() {
        let inv = 1.0 / 9.0; // d_cut = 3
        assert_eq!(gaussian_weight(0.0, inv), GAUSS_SCALE as u64);
        let at_edge = gaussian_weight(9.0, inv);
        assert_eq!(at_edge, (GAUSS_SCALE / std::f64::consts::E).round() as u64);
        assert!(at_edge >= 1, "in-ball weights must stay positive (monotonicity)");
        assert!(gaussian_weight(1.0, inv) > gaussian_weight(4.0, inv));
    }

    #[test]
    fn epanechnikov_weight_bounds_and_monotonicity() {
        let inv = 1.0 / 9.0; // d_cut = 3
        assert_eq!(epanechnikov_weight(0.0, inv), GAUSS_SCALE as u64);
        // Zero exactly at the boundary (a 0 contribution never lowers ρ, so
        // monotonicity survives), positive strictly inside.
        assert_eq!(epanechnikov_weight(9.0, inv), 0);
        assert!(epanechnikov_weight(8.99, inv) >= 1);
        assert!(epanechnikov_weight(1.0, inv) > epanechnikov_weight(4.0, inv));
        // The parabola at the half-radius point: 4096 · (1 − 1/4).
        assert_eq!(epanechnikov_weight(9.0 / 4.0, inv), 3072);
    }

    #[test]
    fn pair_weight_dispatches_per_model() {
        let inv = 1.0 / 4.0;
        assert_eq!(pair_weight(DensityModel::CutoffCount, 1.0, inv), 1);
        assert_eq!(pair_weight(DensityModel::GaussianKernel, 1.0, inv), gaussian_weight(1.0, inv));
        assert_eq!(pair_weight(DensityModel::Epanechnikov, 1.0, inv), epanechnikov_weight(1.0, inv));
    }

    #[test]
    fn saturate_rho_is_a_min() {
        assert_eq!(saturate_rho(0), 0);
        assert_eq!(saturate_rho(u32::MAX as u64), u32::MAX);
        assert_eq!(saturate_rho(u32::MAX as u64 + 1), u32::MAX);
    }

    #[test]
    fn knn_ranks_share_on_ties_and_invert_distance_order() {
        // d_k values: 5.0 (sparse), 1.0, 1.0 (tied), 0.5 (densest).
        let rho = knn_rank_densities(&[5.0f64, 1.0, 1.0, 0.5]);
        assert_eq!(rho, vec![0, 1, 1, 3]);
        // Infinity (fewer than k neighbors) ranks sparsest.
        let rho = knn_rank_densities(&[f64::INFINITY, 2.0, f64::INFINITY]);
        assert_eq!(rho, vec![0, 2, 0]);
    }

    #[test]
    fn tree_and_naive_agree_for_knn_and_gauss() {
        let mut rng = SplitMix64::new(141);
        let pts = gen_uniform_points(&mut rng, 400, 2, 40.0);
        for model in
            [DensityModel::KnnRadius { k: 5 }, DensityModel::GaussianKernel, DensityModel::Epanechnikov]
        {
            let a = compute_density_model(&pts, 4.0, model, DensityAlgo::Naive);
            for algo in [DensityAlgo::TreePruned, DensityAlgo::TreeNoPrune, DensityAlgo::BaselineIncremental] {
                let b = compute_density_model(&pts, 4.0, model, algo);
                assert_eq!(a, b, "{model} under {algo:?}");
            }
        }
    }

    #[test]
    fn cutoff_model_is_verbatim_compute_density() {
        let mut rng = SplitMix64::new(142);
        let pts = gen_degenerate_points(&mut rng, 120, 2);
        for algo in DensityAlgo::ALL {
            assert_eq!(
                compute_density_model(&pts, 2.0, DensityModel::CutoffCount, algo),
                super::super::compute_density(&pts, 2.0, algo),
                "{algo:?}"
            );
        }
    }

    #[test]
    fn knn_with_k_past_n_ranks_everything_equal() {
        let mut rng = SplitMix64::new(143);
        let pts = gen_uniform_points(&mut rng, 10, 2, 10.0);
        let rho = compute_density_model(&pts, 1.0, DensityModel::KnnRadius { k: 64 }, DensityAlgo::TreePruned);
        assert!(rho.iter().all(|&r| r == 0), "{rho:?}");
    }
}
